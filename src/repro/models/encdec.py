"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv audio frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, n_frames, d_model).  The encoder
is bidirectional; the decoder has causal self-attention + cross-attention.
Positions: sinusoidal (encoder) / learned table (decoder) — whisper uses no
RoPE (cfg.rope_fraction = 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def _sinusoid(n, d):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(1, d // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def _enc_block_init(key, cfg):
    ks = layers.split(key, 2)
    p, a = {}, {}
    p["attn"], a["attn"] = layers.attention_init(ks[0], cfg)
    p["ffn"], a["ffn"] = layers.mlp_init(ks[1], cfg)
    for n in ("ln1", "ln2"):
        p[n] = jnp.ones((cfg.d_model,), cfg.param_dtype); a[n] = (None,)
        p[n + "_b"] = jnp.zeros((cfg.d_model,), cfg.param_dtype); a[n + "_b"] = (None,)
    return p, a


def _dec_block_init(key, cfg):
    ks = layers.split(key, 3)
    p, a = {}, {}
    p["self"], a["self"] = layers.attention_init(ks[0], cfg)
    p["cross"], a["cross"] = layers.attention_init(ks[1], cfg)
    p["ffn"], a["ffn"] = layers.mlp_init(ks[2], cfg)
    for n in ("ln1", "ln2", "ln3"):
        p[n] = jnp.ones((cfg.d_model,), cfg.param_dtype); a[n] = (None,)
        p[n + "_b"] = jnp.zeros((cfg.d_model,), cfg.param_dtype); a[n + "_b"] = (None,)
    return p, a


def init(key, cfg):
    ed = cfg.encdec
    ks = layers.split(key, 5)
    params, axes = {}, {}
    params["embed"], axes["embed"] = layers.embed_init(ks[0], cfg)
    params["pos_dec"] = (jax.random.normal(ks[1], (ed.max_dec_len, cfg.d_model))
                         * 0.01).astype(cfg.param_dtype)
    axes["pos_dec"] = (None, "embed")
    from repro.models.lm import _stacked_init  # shared stacking helper
    params["enc"], axes["enc"] = _stacked_init(
        ks[2], ed.n_enc_layers, lambda k: _enc_block_init(k, cfg))
    params["dec"], axes["dec"] = _stacked_init(
        ks[3], cfg.n_layers, lambda k: _dec_block_init(k, cfg))
    for n in ("ln_enc", "ln_f"):
        params[n] = jnp.ones((cfg.d_model,), cfg.param_dtype); axes[n] = (None,)
        params[n + "_b"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
        axes[n + "_b"] = (None,)
    return params, axes


# --------------------------------------------------------------------------- #
# encoder
# --------------------------------------------------------------------------- #
def encode(params, frames, cfg, env):
    """frames: (B, F, D) precomputed embeddings (stub frontend)."""
    x = frames.astype(cfg.compute_dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(cfg.compute_dtype)[None]
    x = env.constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])

    def body(carry, p):
        h = carry
        hh = layers.layer_norm(h, p["ln1"], p["ln1_b"])
        q, k, v = layers.qkv_project(p["attn"], hh, cfg, positions, env)
        att = layers.chunked_attention(q, k, v, causal=False,
                                       kv_chunk=cfg.attn_kv_chunk)
        h = h + layers.attn_output(p["attn"], att, cfg)
        hh = layers.layer_norm(h, p["ln2"], p["ln2_b"])
        h = env.constrain(h + layers.mlp_apply(p["ffn"], hh, cfg),
                          ("batch", "seq", None))
        return h, None

    fn = jax.checkpoint(body) if env.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc"])
    return layers.layer_norm(x, params["ln_enc"], params["ln_enc_b"])


# --------------------------------------------------------------------------- #
# decoder blocks
# --------------------------------------------------------------------------- #
def _dec_block(p, x, enc_out, cfg, env, positions, *, self_kv=None, pos=None):
    """One decoder layer.  Training/prefill when self_kv is None; decode when
    (kc, vc) caches are given (returns updated caches)."""
    hh = layers.layer_norm(x, p["ln1"], p["ln1_b"])
    q, k, v = layers.qkv_project(p["self"], hh, cfg, positions, env)
    new_kv = None
    if self_kv is None:
        att = layers.chunked_attention(q, k, v, causal=True,
                                       kv_chunk=cfg.attn_kv_chunk)
        new_kv = (k, v)
    else:
        kc, vc = self_kv
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        att = layers.decode_attention(q, kc, vc, pos + 1)
        new_kv = (kc, vc)
    x = x + layers.attn_output(p["self"], att, cfg)

    hh = layers.layer_norm(x, p["ln2"], p["ln2_b"])
    cd = cfg.compute_dtype
    qx = jnp.einsum("bsd,dhk->bshk", hh, p["cross"]["wq"].astype(cd))
    kx = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(cd))
    vx = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(cd))
    xatt = layers.chunked_attention(qx, kx, vx, causal=False,
                                    kv_chunk=cfg.attn_kv_chunk)
    x = x + layers.attn_output(p["cross"], xatt, cfg)

    hh = layers.layer_norm(x, p["ln3"], p["ln3_b"])
    x = env.constrain(x + layers.mlp_apply(p["ffn"], hh, cfg),
                      ("batch", None, None))
    return x, new_kv


def forward(params, batch, cfg, env):
    """batch: dict(tokens (B,S), enc_frames (B,F,D)) -> (logits, aux)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    enc_out = encode(params, batch["enc_frames"], cfg, env)
    x = layers.embed_lookup(params["embed"], tokens, cfg)
    x = x + params["pos_dec"][:s].astype(cfg.compute_dtype)[None]
    x = env.constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, p):
        h, _ = carry
        h, _kv = _dec_block(p, h, enc_out, cfg, env, positions)
        return (h, jnp.float32(0)), None

    fn = jax.checkpoint(body) if env.remat else body
    (x, _), _ = jax.lax.scan(fn, (x, jnp.float32(0)), params["dec"])
    x = layers.layer_norm(x, params["ln_f"], params["ln_f_b"])
    logits = layers.unembed(params["embed"], x, cfg)
    return env.constrain(logits, ("batch", None, "vocab")), jnp.float32(0)


def loss_fn(params, batch, cfg, env):
    logits, _ = forward(params, batch, cfg, env)
    labels = batch["tokens"][:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def cache_spec(cfg, batch, max_len, env=None):
    ed = cfg.encdec
    cd = cfg.compute_dtype
    kv = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.head_dim)
    ck = (cfg.n_layers, batch, ed.n_frames, cfg.n_kv, cfg.head_dim)
    ax = (None, "batch", None, "kv_heads", None)
    shapes = {
        "k": jax.ShapeDtypeStruct(kv, cd), "v": jax.ShapeDtypeStruct(kv, cd),
        "enc_k": jax.ShapeDtypeStruct(ck, cd), "enc_v": jax.ShapeDtypeStruct(ck, cd),
    }
    axes = {"k": ax, "v": ax, "enc_k": ax, "enc_v": ax}
    return shapes, axes


def prefill(params, batch, cfg, env, max_len):
    """Encode + run decoder context; cache = self KV + precomputed cross KV."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    enc_out = encode(params, batch["enc_frames"], cfg, env)
    x = layers.embed_lookup(params["embed"], tokens, cfg)
    x = x + params["pos_dec"][:s].astype(cfg.compute_dtype)[None]
    x = env.constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    cd = cfg.compute_dtype

    def body(h, p):
        h, (k, v) = _dec_block(p, h, enc_out, cfg, env, positions)
        kx = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(cd))
        vx = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(cd))
        pad = max_len - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return h, (k, v, kx, vx)

    x, (ks, vs, kxs, vxs) = jax.lax.scan(body, x, params["dec"])
    cache = {"k": ks, "v": vs, "enc_k": kxs, "enc_v": vxs}
    x = layers.layer_norm(x[:, -1:], params["ln_f"], params["ln_f_b"])
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    return logits, cache


def decode_step(params, cache, token, pos, cfg, env):
    b = token.shape[0]
    cd = cfg.compute_dtype
    x = layers.embed_lookup(params["embed"], token, cfg)
    x = x + jax.lax.dynamic_slice(params["pos_dec"], (pos, 0),
                                  (1, cfg.d_model)).astype(cd)[None]
    x = env.constrain(x, ("batch", "seq", None))
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(h, inp):
        p, kc, vc, kx, vx = inp
        hh = layers.layer_norm(h, p["ln1"], p["ln1_b"])
        q, k, v = layers.qkv_project(p["self"], hh, cfg, positions, env)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        att = layers.decode_attention(q, kc, vc, pos + 1)
        h = h + layers.attn_output(p["self"], att, cfg)
        hh = layers.layer_norm(h, p["ln2"], p["ln2_b"])
        qx = jnp.einsum("bsd,dhk->bshk", hh, p["cross"]["wq"].astype(cd))
        xatt = layers.decode_attention(qx, kx, vx, kx.shape[1])
        h = h + layers.attn_output(p["cross"], xatt, cfg)
        hh = layers.layer_norm(h, p["ln3"], p["ln3_b"])
        h = h + layers.mlp_apply(p["ffn"], hh, cfg)
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"],
                  cache["enc_k"], cache["enc_v"]))
    cache = dict(cache, k=ks, v=vs)
    x = layers.layer_norm(x, params["ln_f"], params["ln_f_b"])
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    return logits, cache
