from repro.models import layers, lm, moe, mla, ssm, encdec

__all__ = ["layers", "lm", "moe", "mla", "ssm", "encdec"]
