"""Multi-head Latent Attention (DeepSeek-V2).

KV is down-projected to a kv_lora_rank latent (+ a decoupled shared rope
head); at decode time attention runs *absorbed* directly in latent space, so
the per-token cache is (kv_lora + dh_rope) floats — replicated over the
model axis (it is shared by all heads) and sharded over data (batch).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


def mla_init(key, cfg):
    a = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = cfg.param_dtype
    ks = layers.split(key, 8)
    params, axes = {}, {}
    # queries (optionally low-rank)
    if a.q_lora:
        params["wdq"], axes["wdq"] = layers.dense_init(ks[0], (d, a.q_lora), ("embed", None), dt)
        params["q_norm"] = jnp.ones((a.q_lora,), dt); axes["q_norm"] = (None,)
        params["wuq"], axes["wuq"] = layers.dense_init(
            ks[1], (a.q_lora, h, a.dh_nope + a.dh_rope), (None, "heads", None), dt)
    else:
        params["wq"], axes["wq"] = layers.dense_init(
            ks[1], (d, h, a.dh_nope + a.dh_rope), ("embed", "heads", None), dt)
    # compressed KV + decoupled rope key
    params["wdkv"], axes["wdkv"] = layers.dense_init(
        ks[2], (d, a.kv_lora + a.dh_rope), ("embed", None), dt)
    params["kv_norm"] = jnp.ones((a.kv_lora,), dt); axes["kv_norm"] = (None,)
    params["wuk"], axes["wuk"] = layers.dense_init(
        ks[3], (a.kv_lora, h, a.dh_nope), (None, "heads", None), dt)
    params["wuv"], axes["wuv"] = layers.dense_init(
        ks[4], (a.kv_lora, h, a.dh_v), (None, "heads", None), dt)
    params["wo"], axes["wo"] = layers.dense_init(
        ks[5], (h, a.dh_v, d), ("heads", None, "embed"), dt)
    return params, axes


def _queries(p, x, cfg, positions):
    a = cfg.mla
    cd = cfg.compute_dtype
    if a.q_lora:
        qd = jnp.einsum("bsd,dr->bsr", x, p["wdq"].astype(cd))
        qd = layers.rms_norm(qd, p["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", qd, p["wuq"].astype(cd))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    q_nope, q_rope = q[..., : a.dh_nope], q[..., a.dh_nope:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(p, x, cfg, positions):
    a = cfg.mla
    cd = cfg.compute_dtype
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(cd))
    c_lat, k_rope = ckv[..., : a.kv_lora], ckv[..., a.kv_lora:]
    c_lat = layers.rms_norm(c_lat, p["kv_norm"])
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_lat, k_rope                      # (B,S,r), (B,S,dh_rope)


def mla_forward(p, x, cfg, env, positions):
    """Training / prefill path: expand latents to per-head K/V, flash attend."""
    a = cfg.mla
    cd = cfg.compute_dtype
    q_nope, q_rope = _queries(p, x, cfg, positions)
    c_lat, k_rope = _latent(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_lat, p["wuk"].astype(cd))
    v = jnp.einsum("bsr,rhk->bshk", c_lat, p["wuv"].astype(cd))
    h = cfg.n_heads
    q = jnp.concatenate([q_nope, jnp.broadcast_to(q_rope, q_rope.shape)], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_nope.shape[:3], a.dh_rope))],
        axis=-1)
    # per-head K here (kv == h): ordinary causal flash attention
    out = layers.chunked_attention(q, k, v, causal=True, kv_chunk=cfg.attn_kv_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd)), (c_lat, k_rope)


def mla_decode(p, x, cache, pos, cfg, env):
    """Absorbed decode: scores in latent space against the compressed cache.

    cache: dict(c_lat=(B,S,r), k_rope=(B,S,dh_rope)); x: (B,1,D)."""
    a = cfg.mla
    cd = cfg.compute_dtype
    positions = pos[None, None] if pos.ndim == 0 else pos
    q_nope, q_rope = _queries(p, x, cfg, positions)           # (B,1,H,*)
    c_new, kr_new = _latent(p, x, cfg, positions)             # (B,1,r)
    c_lat = jax.lax.dynamic_update_slice(cache["c_lat"], c_new, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, pos, 0))

    # absorb W_UK into q:  (B,1,H,nope) x (r,H,nope) -> (B,1,H,r)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"].astype(cd))
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat, c_lat)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    scale = 1.0 / math.sqrt(a.dh_nope + a.dh_rope)
    s = (s_lat + s_rope).astype(jnp.float32) * scale          # (B,H,1,S)
    mask = jnp.arange(c_lat.shape[1]) <= pos
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(cd)
    o_lat = jnp.einsum("bhst,btr->bshr", w, c_lat)            # (B,1,H,r)
    o = jnp.einsum("bshr,rhk->bshk", o_lat, p["wuv"].astype(cd))
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cd))
    return y, {"c_lat": c_lat, "k_rope": k_rope}


def mla_cache_shape(cfg, batch, max_len):
    a = cfg.mla
    return {
        "c_lat": ((batch, max_len, a.kv_lora), ("batch", None, None)),
        "k_rope": ((batch, max_len, a.dh_rope), ("batch", None, None)),
    }
