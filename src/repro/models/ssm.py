"""Mamba-2 (SSD — state-space duality) block, chunked, channel-parallel.

With n_groups=1, B/C are shared across heads and the per-channel recurrence
  h[t] = exp(dt[t]*A_head) * h[t-1] + dt[t] * B[t] * x[t]
  y[t] = C[t] . h[t] + D_head * x[t]
is independent per d_inner channel, so state (B, d_inner, N) shards cleanly
on the mesh "model" axis (logical axis "dinner") — the TPU-native layout
(DESIGN.md #4).  The chunked SSD form computes intra-chunk interactions as a
masked quadratic attention-like product and carries inter-chunk states with
a lax.scan; ``repro.kernels.ssd`` provides the Pallas intra-chunk kernel and
reuses ``ssd_ref`` below as its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #
def ssm_init(key, cfg):
    s = cfg.ssm
    d, di, n = cfg.d_model, s.d_inner, s.n_state
    h = di // s.headdim
    dt = cfg.param_dtype
    ks = layers.split(key, 8)
    params, axes = {}, {}
    # separate projections (vs the fused w_in of the reference impl): each
    # output dim shards independently on "model" ("dinner"), keeping TP clean
    params["w_z"], axes["w_z"] = layers.dense_init(ks[0], (d, di), ("embed", "dinner"), dt)
    params["w_x"], axes["w_x"] = layers.dense_init(ks[1], (d, di), ("embed", "dinner"), dt)
    params["w_B"], axes["w_B"] = layers.dense_init(ks[2], (d, n), ("embed", None), dt)
    params["w_C"], axes["w_C"] = layers.dense_init(ks[3], (d, n), ("embed", None), dt)
    params["w_dt"], axes["w_dt"] = layers.dense_init(ks[4], (d, h), ("embed", None), dt)
    for nm, width in (("conv_x", di), ("conv_B", n), ("conv_C", n)):
        params[nm] = (jax.random.normal(ks[5], (s.conv_width, width), jnp.float32)
                      * 0.1).astype(dt)
        axes[nm] = (None, "dinner" if nm == "conv_x" else None)
    params["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32))
    axes["A_log"] = (None,)
    params["D"] = jnp.ones((h,), jnp.float32); axes["D"] = (None,)
    params["dt_bias"] = jnp.zeros((h,), jnp.float32); axes["dt_bias"] = (None,)
    params["norm"] = jnp.ones((di,), dt); axes["norm"] = ("dinner",)
    params["w_out"], axes["w_out"] = layers.dense_init(ks[6], (di, d), ("dinner", "embed"), dt)
    return params, axes


def _causal_conv(xbc, conv_w, conv_state=None):
    """Depthwise causal conv, width W.  xbc: (B,L,C); conv_w: (W,C).

    conv_state (B,W-1,C) carries history for decode; returns (y, new_state)."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)
    new_state = xp[:, -(w - 1):] if w > 1 else pad
    y = sum(xp[:, i: i + xbc.shape[1]] * conv_w[i][None, None] for i in range(w))
    return jax.nn.silu(y), new_state


# --------------------------------------------------------------------------- #
# chunked SSD forward (reference semantics; also the kernel oracle)
# --------------------------------------------------------------------------- #
def ssd_ref(x, dt, A, B, C, chunk):
    """SSD scan.

    x: (b, l, h, p); dt: (b, l, h) (softplus already applied);
    A: (h,) negative decay rates; B, C: (b, l, n)  [n_groups == 1].
    Returns y: (b, l, h, p) and final state (b, h, p, n), fp32.
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    rem = l % chunk
    if rem:
        # process the trailing partial chunk separately (exact, causal)
        y_main, h_main = ssd_ref(x[:, : l - rem], dt[:, : l - rem], A,
                                 B[:, : l - rem], C[:, : l - rem], chunk)
        y_tail, h_tail = _ssd_one_chunk(
            x[:, l - rem:], dt[:, l - rem:], A, B[:, l - rem:], C[:, l - rem:],
            h_main)
        return jnp.concatenate([y_main, y_tail], axis=1), h_tail
    if l == 0:
        return (jnp.zeros_like(x, dtype=jnp.float32),
                jnp.zeros((b, h, p, n), jnp.float32))
    nc = l // chunk
    # scan over chunks: peak score memory is O(b * chunk^2 * h) per step
    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    Bf = B.astype(jnp.float32).reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    Cf = C.astype(jnp.float32).reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, :, :, None]     # (1,i,j,1)

    def step(hstate, inp):
        xc, dtc, Bc, Cc = inp                                     # per-chunk slices
        dA = dtc * A[None, None, :]                               # (b,q,h)
        cs = jnp.cumsum(dA, axis=1)                               # inclusive
        # intra-chunk: y[i] += sum_{j<=i} C_i.B_j exp(cs_i-cs_j) dt_j x_j
        # mask INSIDE the exp: masked (j>i) entries have decay>0 and would
        # overflow to inf, poisoning gradients through the where
        decay = jnp.where(causal, cs[:, :, None, :] - cs[:, None, :, :],
                          -jnp.inf)                               # (b,i,j,h)
        L = jnp.exp(decay)
        cb = jnp.einsum("bin,bjn->bij", Cc, Bc)
        att = cb[..., None] * L * dtc[:, None, :, :]              # (b,i,j,h)
        y = jnp.einsum("bijh,bjhp->bihp", att, xc)
        # inter-chunk: contribution of the state entering this chunk
        y = y + jnp.einsum("bin,bhpn->bihp", Cc, hstate) * jnp.exp(cs)[..., None]
        # state update
        last = cs[:, -1, :]                                       # (b,h)
        w = jnp.exp(last[:, None, :] - cs) * dtc                  # (b,q,h)
        S = jnp.einsum("bjh,bjn,bjhp->bhpn", w, Bc, xc)
        hstate = hstate * jnp.exp(last)[..., None, None] + S
        return hstate, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hfinal, ys = jax.lax.scan(step, h0, (xf, dtf, Bf, Cf))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, h, p)
    return y, hfinal


def _ssd_one_chunk(x, dt, A, B, C, h0):
    """Single (possibly partial) chunk with an incoming state h0."""
    b, q, h, p = x.shape
    xc = x.astype(jnp.float32)
    dtc = dt.astype(jnp.float32)
    Bc = B.astype(jnp.float32)
    Cc = C.astype(jnp.float32)
    dA = dtc * A[None, None, :]
    cs = jnp.cumsum(dA, axis=1)
    idx = jnp.arange(q)
    causal = (idx[:, None] >= idx[None, :])[None, :, :, None]
    decay = jnp.where(causal, cs[:, :, None, :] - cs[:, None, :, :],
                      -jnp.inf)
    L = jnp.exp(decay)
    cb = jnp.einsum("bin,bjn->bij", Cc, Bc)
    att = cb[..., None] * L * dtc[:, None, :, :]
    y = jnp.einsum("bijh,bjhp->bihp", att, xc)
    y = y + jnp.einsum("bin,bhpn->bihp", Cc, h0) * jnp.exp(cs)[..., None]
    last = cs[:, -1, :]
    w = jnp.exp(last[:, None, :] - cs) * dtc
    S = jnp.einsum("bjh,bjn,bjhp->bhpn", w, Bc, xc)
    hfinal = h0 * jnp.exp(last)[..., None, None] + S
    return y, hfinal


def _project(p, x, cfg, conv_state=None):
    """Shared projection + causal conv.  conv_state: None or dict(x,B,C)."""
    s = cfg.ssm
    cd = cfg.compute_dtype
    z = jnp.einsum("bld,dk->blk", x, p["w_z"].astype(cd))
    xs = jnp.einsum("bld,dk->blk", x, p["w_x"].astype(cd))
    B = jnp.einsum("bld,dk->blk", x, p["w_B"].astype(cd))
    C = jnp.einsum("bld,dk->blk", x, p["w_C"].astype(cd))
    dtr = jnp.einsum("bld,dk->blk", x, p["w_dt"].astype(cd))
    cs = conv_state or {}
    xs, ncx = _causal_conv(xs, p["conv_x"].astype(cd), cs.get("x"))
    B, ncb = _causal_conv(B, p["conv_B"].astype(cd), cs.get("B"))
    C, ncc = _causal_conv(C, p["conv_C"].astype(cd), cs.get("C"))
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"][None, None])
    return z, xs, B, C, dt, {"x": ncx, "B": ncb, "C": ncc}


def ssm_forward(p, x, cfg, env, conv_state=None, ssd_state=None):
    """Full mamba2 mixer.  x: (B,L,D) -> (B,L,D).

    When conv_state/ssd_state are provided (decode continuation) they are
    threaded; for training they are None and zero-initialised."""
    s = cfg.ssm
    cd = cfg.compute_dtype
    di = s.d_inner
    h = di // s.headdim
    z, xs, B, C, dt, new_conv = _project(p, x, cfg, conv_state)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(*xs.shape[:2], h, s.headdim)
    # channel-parallel SSD: keep headdim sharded on "model" through the scan
    xh = env.constrain(xh, ("batch", None, None, "dinner"))
    y, hfinal = ssd_ref(xh, dt, A, B, C, s.chunk)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = env.constrain(y, ("batch", None, None, "dinner"))
    y = y.reshape(*xs.shape).astype(cd)
    y = y * jax.nn.silu(z)
    y = layers.rms_norm(y, p["norm"])
    out = jnp.einsum("bld,dk->blk", y, p["w_out"].astype(cd))
    return out, (new_conv, hfinal)


def ssm_decode(p, x, state, cfg, env):
    """Single-token recurrent step.  x: (B,1,D); state=(conv_state, h)."""
    s = cfg.ssm
    cd = cfg.compute_dtype
    di = s.d_inner
    h = di // s.headdim
    conv_state, hstate = state
    z, xs, B, C, dt, new_conv = _project(p, x, cfg, conv_state)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(xs.shape[0], h, s.headdim).astype(jnp.float32)
    dA = jnp.exp(dt[:, 0, :] * A[None])                          # (B,h)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], B[:, 0].astype(jnp.float32), xh)
    hnew = hstate * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), hnew)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(xs.shape[0], 1, di).astype(cd)
    y = y * jax.nn.silu(z)
    y = layers.rms_norm(y, p["norm"])
    out = jnp.einsum("bld,dk->blk", y, p["w_out"].astype(cd))
    return out, (new_conv, hnew)


def ssm_state_shape(cfg, batch):
    s = cfg.ssm
    h = s.d_inner // s.headdim
    w = s.conv_width - 1
    return {
        "conv_x": ((batch, w, s.d_inner), ("batch", None, "dinner")),
        "conv_B": ((batch, w, s.n_state), ("batch", None, None)),
        "conv_C": ((batch, w, s.n_state), ("batch", None, None)),
        "h": ((batch, h, s.headdim, s.n_state), ("batch", None, "dinner", None)),
    }
