"""Shared transformer building blocks (norms, RoPE, GQA attention, MLPs).

Conventions
-----------
* Parameters are nested dicts of jnp arrays.  Every init function returns
  ``(params, axes)`` where ``axes`` mirrors ``params`` with tuples of
  *logical* axis names consumed by ``repro.parallel.sharding.MeshEnv``.
* Activations flow in ``cfg.compute_dtype`` (bf16); softmax statistics and
  normalization accumulate in fp32.
* Attention is O(seq * chunk) memory via an online-softmax scan over KV
  chunks (the pure-XLA analogue of the Pallas flash kernel in
  ``repro.kernels.flash_attention`` — the kernel's ``ref.py`` reuses the
  naive oracle here).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #
def dense_init(key, shape, axes, dtype, scale=None):
    fan_in = shape[0] if len(shape) > 1 else 1
    if scale is None:
        scale = 1.0 / math.sqrt(max(1, fan_in))
    w = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    return w.astype(dtype), axes


def split(key, n):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def rms_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- #
# RoPE (with partial-rotary support for chatglm3's "2d" rope)
# --------------------------------------------------------------------------- #
def rope_freqs(dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta=10000.0, fraction=1.0):
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = rope_freqs(rot, theta)                       # (rot/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


# --------------------------------------------------------------------------- #
# attention parameter init
# --------------------------------------------------------------------------- #
def attention_init(key, cfg):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = split(key, 5)
    params, axes = {}, {}
    params["wq"], axes["wq"] = dense_init(ks[0], (d, h, dh), ("embed", "heads", None), cfg.param_dtype)
    params["wk"], axes["wk"] = dense_init(ks[1], (d, kv, dh), ("embed", "kv_heads", None), cfg.param_dtype)
    params["wv"], axes["wv"] = dense_init(ks[2], (d, kv, dh), ("embed", "kv_heads", None), cfg.param_dtype)
    params["wo"], axes["wo"] = dense_init(ks[3], (h, dh, d), ("heads", None, "embed"), cfg.param_dtype)
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((dh,), cfg.param_dtype)
        params["k_norm"] = jnp.ones((dh,), cfg.param_dtype)
        axes["q_norm"] = (None,)
        axes["k_norm"] = (None,)
    return params, axes


def qkv_project(p, x, cfg, positions, env=None):
    """x: (B,S,D) -> q (B,S,H,dh), k/v (B,S,KV,dh) with rope + optional qk-norm.

    With env given, q/k/v are constrained to head-sharded layout — without
    this XLA may keep seq sharded through attention and replicate the head
    dim (observed on deepseek-v2: 128 unsharded heads in the score buffers).
    """
    cd = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if env is not None:
        q = env.constrain(q, ("batch", None, "heads", None))
        k = env.constrain(k, ("batch", None, "kv_heads", None))
        v = env.constrain(v, ("batch", None, "kv_heads", None))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope_fraction > 0:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def attn_output(p, attn, cfg):
    return jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(cfg.compute_dtype))


# --------------------------------------------------------------------------- #
# attention cores
# --------------------------------------------------------------------------- #
def naive_attention(q, k, v, *, causal=True, window=None, q_pos0=0, kv_pos0=0):
    """O(S^2)-memory oracle.  q: (B,Sq,H,dh), k/v: (B,Sk,KV,dh)."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(dh)
    qpos = q_pos0 + jnp.arange(sq)
    kpos = kv_pos0 + jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(q.dtype), v)
    return out.reshape(b, sq, h, v.shape[-1])


def chunked_attention(q, k, v, *, causal=True, kv_chunk=512, q_pos0=0, kv_pos0=0):
    """Flash attention in pure XLA: online-softmax scan over KV chunks with a
    custom VJP that RECOMPUTES blockwise in the backward pass (saving only
    (q,k,v,out,lse)) — without it, scan-backward stacks the fp32 (m,l,acc)
    carries per chunk (observed: tens of GB/chip on deepseek-v2 train_4k).
    The Pallas kernel in repro.kernels.flash_attention is the TPU-native
    version of exactly this schedule."""
    if q_pos0 == 0 and kv_pos0 == 0:
        return _make_flash(causal, int(kv_chunk))(q, k, v)
    return _chunked_attention_core(q, k, v, causal=causal, kv_chunk=kv_chunk,
                                   q_pos0=q_pos0, kv_pos0=kv_pos0)[0]


def _chunked_attention_core(q, k, v, *, causal=True, kv_chunk=512, q_pos0=0,
                            kv_pos0=0):
    """Returns (out, lse) — shared by the flash fwd and the plain path."""
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    kv_chunk = min(kv_chunk, sk)
    if sk % kv_chunk != 0:          # pad to a multiple (masked out)
        pad = kv_chunk - sk % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sk_p = sk + pad
    else:
        sk_p = sk
    nkv = sk_p // kv_chunk
    kc = k.reshape(b, nkv, kv_chunk, kvh, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nkv, kv_chunk, kvh, v.shape[-1]).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(b, sq, kvh, g, dh)
    qpos = (q_pos0 + jnp.arange(sq)).astype(jnp.int32)
    scale = 1.0 / math.sqrt(dh)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        kpos = kv_pos0 + j * kv_chunk + jnp.arange(kv_chunk, dtype=jnp.int32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj).astype(jnp.float32) * scale
        valid = kpos[None, :] < sk + kv_pos0
        mask = valid
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    dv = v.shape[-1]
    m0 = jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nkv, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))                 # (b,kvh,g,sq)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv).astype(q.dtype)
    return out, lse


@functools.lru_cache(maxsize=64)
def _make_flash(causal, kv_chunk):
    """custom_vjp flash attention closed over static (causal, kv_chunk)."""

    @jax.custom_vjp
    def fa(q, k, v):
        return _chunked_attention_core(q, k, v, causal=causal,
                                       kv_chunk=kv_chunk)[0]

    def fwd(q, k, v):
        out, lse = _chunked_attention_core(q, k, v, causal=causal,
                                           kv_chunk=kv_chunk)
        return out, (q, k, v, out, lse)

    def bwd(res, do):
        q, k, v, out, lse = res
        b, sq, h, dh = q.shape
        sk, kvh = k.shape[1], k.shape[2]
        g = h // kvh
        dv_dim = v.shape[-1]
        c = min(kv_chunk, sk)
        pad = (-sk) % c
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nkv = (sk + pad) // c
        kc = kp.reshape(b, nkv, c, kvh, dh).transpose(1, 0, 2, 3, 4)
        vc = vp.reshape(b, nkv, c, kvh, dv_dim).transpose(1, 0, 2, 3, 4)

        qg = q.reshape(b, sq, kvh, g, dh)
        dog = do.reshape(b, sq, kvh, g, dv_dim).astype(jnp.float32)
        og = out.reshape(b, sq, kvh, g, dv_dim).astype(jnp.float32)
        D = jnp.sum(dog * og, axis=-1).transpose(0, 2, 3, 1)   # (b,kvh,g,sq)
        qpos = jnp.arange(sq, dtype=jnp.int32)
        scale = 1.0 / math.sqrt(dh)

        def step(dq_acc, xs):
            kj, vj, j = xs
            kpos = j * c + jnp.arange(c, dtype=jnp.int32)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj).astype(jnp.float32)
            s = s * scale
            mask = kpos[None, :] < sk
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            s = jnp.where(mask[None, None, None], s, -1e30)
            p = jnp.exp(s - lse[..., None])                    # (b,h,g,q,k)
            p = jnp.where(mask[None, None, None], p, 0.0)
            dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p, dog)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dog.astype(q.dtype),
                            vj).astype(jnp.float32)
            ds = p * (dp - D[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kj)
            dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg.astype(jnp.float32))
            return dq_acc, (dk_j, dv_j)

        dq0 = jnp.zeros((b, sq, kvh, g, dh), jnp.float32)
        dq, (dks, dvs) = jax.lax.scan(
            step, dq0, (kc, vc, jnp.arange(nkv, dtype=jnp.int32)))
        dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, sk + pad, kvh, dh)[:, :sk]
        dvv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, sk + pad, kvh, dv_dim)[:, :sk]
        return (dq.reshape(b, sq, h, dh).astype(q.dtype),
                dk.astype(k.dtype), dvv.astype(v.dtype))

    fa.defvjp(fwd, bwd)
    return fa


def windowed_attention(q, k, v, *, window, q_chunk=512, q_pos0=0,
                       prefix_kv=None):
    """Sliding-window causal attention, FLOP-proportional to the window.

    Scans over q blocks; for each, dynamic-slices the [pos-window, pos] KV
    range (front-padded so the slice is static-size).  Differentiable.
    q and k/v must share the same positions (self-attention).

    prefix_kv: optional (k_pre, v_pre) of shape (B, P, KV, dh) — globally
    visible prefix keys (hymba meta tokens) attended by every q block.
    """
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q_chunk = min(q_chunk, sq)
    if sq % q_chunk:
        raise ValueError("seq must divide q_chunk for windowed attention")
    w = (window + q_chunk - 1) // q_chunk * q_chunk   # round window up to blocks
    kp = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    nq = sq // q_chunk
    qb = q.reshape(b, nq, q_chunk, h, dh).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(dh)
    span = w + q_chunk
    npre = 0 if prefix_kv is None else prefix_kv[0].shape[1]
    dv = v.shape[-1]

    def step(i, qi):
        start = i * q_chunk                      # in padded coords == pos - w
        kj = jax.lax.dynamic_slice(kp, (0, start, 0, 0), (b, span, kvh, dh))
        vj = jax.lax.dynamic_slice(vp, (0, start, 0, 0), (b, span, kvh, dv))
        qpos = q_pos0 + start + jnp.arange(q_chunk)
        kpos = q_pos0 + start - w + jnp.arange(span)
        mask = (kpos[None, :] <= qpos[:, None]) \
            & (kpos[None, :] > qpos[:, None] - window) \
            & (kpos[None, :] >= q_pos0)
        if prefix_kv is not None:
            kj = jnp.concatenate([prefix_kv[0], kj], axis=1)
            vj = jnp.concatenate([prefix_kv[1], vj], axis=1)
            mask = jnp.concatenate(
                [jnp.ones((q_chunk, npre), bool), mask], axis=1)
        qg = qi.reshape(b, q_chunk, kvh, g, dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj).astype(jnp.float32) * scale
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(q.dtype), vj)
        return o.reshape(b, q_chunk, h, dv)

    out = jax.lax.map(lambda args: step(*args),
                      (jnp.arange(nq, dtype=jnp.int32), qb))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)


def prefill_attention(q, k, v, *, kv_chunk=1024):
    """Causal attention over a static *triangular pair schedule*: one scan of
    exactly nq*(nq+1)/2 block-pair steps — FLOP-exact (no masked-out block is
    ever computed) and statically countable by repro.costmodel (no while
    loops).  Online-softmax stats for all q blocks live in the carry and are
    updated in place per step."""
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    if sq != sk or sq % kv_chunk:
        return chunked_attention(q, k, v, causal=True, kv_chunk=kv_chunk)
    g = h // kvh
    dv = v.shape[-1]
    n = sq // kv_chunk
    c = kv_chunk
    qg = q.reshape(b, n, c, kvh, g, dh)
    scale = 1.0 / math.sqrt(dh)

    # static triangular schedule
    qi_list, kj_list = [], []
    for qi in range(n):
        for kj in range(qi + 1):
            qi_list.append(qi)
            kj_list.append(kj)
    qi_arr = jnp.asarray(qi_list, jnp.int32)
    kj_arr = jnp.asarray(kj_list, jnp.int32)
    diag = qi_arr == kj_arr
    tri = jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]

    def step(carry, xs):
        m, l, acc = carry                       # (b,kvh,g,n,c[,dv])
        qi, kj, is_diag = xs
        qb = jax.lax.dynamic_slice(
            qg, (0, qi, 0, 0, 0, 0), (b, 1, c, kvh, g, dh))[:, 0]
        kb = jax.lax.dynamic_slice(k, (0, kj * c, 0, 0), (b, c, kvh, dh))
        vb = jax.lax.dynamic_slice(v, (0, kj * c, 0, 0), (b, c, kvh, dv))
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32) * scale
        s = jnp.where(jnp.logical_or(~is_diag, tri)[None, None, None], s, -1e30)
        m_blk = jax.lax.dynamic_slice(
            m, (0, 0, 0, qi, 0), (b, kvh, g, 1, c))[..., 0, :]
        l_blk = jax.lax.dynamic_slice(
            l, (0, 0, 0, qi, 0), (b, kvh, g, 1, c))[..., 0, :]
        a_blk = jax.lax.dynamic_slice(
            acc, (0, 0, 0, qi, 0, 0), (b, kvh, g, 1, c, dv))[..., 0, :, :]
        m_new = jnp.maximum(m_blk, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_blk - m_new)
        l_new = l_blk * corr + p.sum(axis=-1)
        a_new = a_blk * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        m = jax.lax.dynamic_update_slice(
            m, m_new[..., None, :], (0, 0, 0, qi, 0))
        l = jax.lax.dynamic_update_slice(
            l, l_new[..., None, :], (0, 0, 0, qi, 0))
        acc = jax.lax.dynamic_update_slice(
            acc, a_new[..., None, :, :], (0, 0, 0, qi, 0, 0))
        return (m, l, acc), None

    m0 = jnp.full((b, kvh, g, n, c), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, n, c), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, n, c, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (qi_arr, kj_arr, diag))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 4, 1, 2, 5).reshape(b, sq, h, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len):
    """Single-token decode against a (replicated or head-sharded) KV cache.

    q: (B,1,H,dh); caches: (B,S,KV,dh); cur_len: () int32 — number of valid
    cache entries (the new token's KV must already be written)."""
    b, _, h, dh = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(dh)
    mask = jnp.arange(s) < cur_len
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(q.dtype), v_cache)
    return out.reshape(b, 1, h, v_cache.shape[-1])


def flash_decode_shardmap(q, k_cache, v_cache, k_new, v_new, pos, env):
    """Flash-decoding: KV cache sharded over the *model* axis along sequence.

    Used when kv_heads does not divide TP (llama3/qwen3/nemotron/chatglm3/
    pixtral at TP=16).  Each model shard holds a contiguous seq slice of the
    cache, writes the new token's KV iff it owns the slot, computes partial
    attention with fp32 (m, l) statistics and combines across the axis with a
    log-sum-exp psum.  Returns (out, new_k_cache, new_v_cache).

    q: (B,1,H,dh) replicated over model; caches: (B,S,KV,dh) sharded (seq);
    k_new/v_new: (B,1,KV,dh); pos: () int32 position of the new token.
    """
    mesh = env.mesh
    axis = env.model_axis

    def body(q, kc, vc, kn, vn, pos):
        # shapes here are per-shard: batch sharded over data, cache seq
        # sharded over model, q/new-KV replicated over model
        b, _, h, dh = q.shape
        kvh = kc.shape[2]
        g = h // kvh
        idx = jax.lax.axis_index(axis)
        s_loc = kc.shape[1]
        start = idx * s_loc
        local = jnp.clip(pos - start, 0, s_loc - 1)
        owner = (pos >= start) & (pos < start + s_loc)
        kc2 = jax.lax.dynamic_update_slice(kc, kn, (0, local, 0, 0))
        vc2 = jax.lax.dynamic_update_slice(vc, vn, (0, local, 0, 0))
        kc = jnp.where(owner, kc2, kc)
        vc = jnp.where(owner, vc2, vc)

        qg = q.reshape(b, kvh, g, dh)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, kc).astype(jnp.float32)
        s *= 1.0 / math.sqrt(dh)
        kpos = start + jnp.arange(s_loc)
        s = jnp.where((kpos <= pos)[None, None, None], s, -1e30)
        m = s.max(axis=-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        o = jnp.einsum("bhgk,bkhd->bhgd", p, vc.astype(jnp.float32))
        m_g = jax.lax.pmax(m, axis)
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, axis)
        o_g = jax.lax.psum(o * w[..., None], axis)
        out = (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q.dtype)
        return out.reshape(b, 1, h, dh), kc, vc

    dspec = env.data_axes if len(env.data_axes) > 1 else env.data_axes[0]
    qs = P(dspec, None, None, None)
    cs = P(dspec, axis, None, None)
    ns = P(dspec, None, None, None)
    from repro.parallel.sharding import shard_map
    return shard_map(
        body, mesh=mesh,
        in_specs=(qs, cs, cs, ns, ns, P()),
        out_specs=(qs, cs, cs),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, pos)


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #
def mlp_init(key, cfg, d_ff=None, d_model=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    params, axes = {}, {}
    if cfg.mlp == "swiglu":
        ks = split(key, 3)
        params["wg"], axes["wg"] = dense_init(ks[0], (d, f), ("embed", "ff"), dt)
        params["wu"], axes["wu"] = dense_init(ks[1], (d, f), ("embed", "ff"), dt)
        params["wd"], axes["wd"] = dense_init(ks[2], (f, d), ("ff", "embed"), dt)
    else:  # relu2 | gelu: two-matrix MLP
        ks = split(key, 2)
        params["wu"], axes["wu"] = dense_init(ks[0], (d, f), ("embed", "ff"), dt)
        params["wd"], axes["wd"] = dense_init(ks[1], (f, d), ("ff", "embed"), dt)
    return params, axes


def mlp_apply(p, x, cfg):
    cd = cfg.compute_dtype
    if cfg.mlp == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(cd))
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(cd))
        h = jax.nn.silu(g) * u
    elif cfg.mlp == "relu2":
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(cd))
        r = jax.nn.relu(u)
        h = r * r
    elif cfg.mlp == "gelu":
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(cd))
        h = jax.nn.gelu(u)
    else:
        raise ValueError(cfg.mlp)
    return jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(cd))


# --------------------------------------------------------------------------- #
# embedding / unembedding
# --------------------------------------------------------------------------- #
def embed_init(key, cfg):
    """Vocab padded to cfg.vocab_pad_to so the table TP-shards cleanly."""
    e = jax.random.normal(key, (cfg.padded_vocab, cfg.d_model), jnp.float32) * 0.02
    return e.astype(cfg.param_dtype), ("vocab", "embed")


def embed_lookup(emb, tokens, cfg):
    return jnp.take(emb.astype(cfg.compute_dtype), tokens, axis=0)


def unembed(emb, x, cfg):
    """Tied unembedding: (B,S,D) @ (V,D)^T -> (B,S,V_padded); padding ids
    masked to -inf so sampling/loss never select them."""
    logits = jnp.einsum("bsd,vd->bsv", x, emb.astype(cfg.compute_dtype))
    if cfg.padded_vocab != cfg.vocab:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits
