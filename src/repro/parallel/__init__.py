from repro.parallel.sharding import MeshEnv, logical_to_spec, param_shardings
from repro.parallel.collectives import parse_collective_bytes

__all__ = ["MeshEnv", "logical_to_spec", "param_shardings", "parse_collective_bytes"]
