"""Parse collective-communication traffic out of post-SPMD HLO text.

``compiled.cost_analysis()`` has no collective-bytes entry, so the roofline's
collective term is derived here: scan the compiled HLO for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops, decode each op's result shape, and convert to *per-chip link bytes*
using the standard ring-algorithm factors:

  all-reduce       2 * s * (n-1)/n   (reduce-scatter + all-gather)
  all-gather       s_out * (n-1)/n
  reduce-scatter   s_in  * (n-1)/n   (~= s_out * (n-1))
  all-to-all       s * (n-1)/n
  collective-permute  s

where s is the (per-shard) tensor size in the SPMD program.  n is read from
the op's replica_groups when present, else the mesh size is used.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g.  %all-gather.3 = bf16[4,1024,512] all-gather(...), replica_groups=...
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_txt):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    per_chip_link_bytes: float = 0.0          # ring-factor adjusted
    raw_bytes: float = 0.0                    # sum of result sizes
    by_kind: dict = field(default_factory=dict)
    count: int = 0

    def as_dict(self) -> dict:
        return {
            "per_chip_link_bytes": self.per_chip_link_bytes,
            "raw_bytes": self.raw_bytes,
            "count": self.count,
            "by_kind": self.by_kind,
        }


def _group_size(line: str, default_n: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1)
        return max(1, len([t for t in first.split(",") if t.strip() != ""]))
    return default_n


def parse_collective_bytes(hlo_text: str, mesh_size: int = 1) -> CollectiveStats:
    stats = CollectiveStats()
    seen_started: set[str] = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        # avoid double counting async start/done pairs: count "-start" once,
        # skip the matching "-done" (whose operand is the start tuple).
        if "-done(" in line:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_txt)
        if size == 0:
            continue
        n = _group_size(line, mesh_size)
        if n <= 1:
            continue
        ring = (n - 1) / n
        if kind == "all-reduce":
            link = 2.0 * size * ring
        elif kind == "all-gather":
            link = size * ring            # size is the gathered output
        elif kind == "reduce-scatter":
            link = size * (n - 1)         # size is the scattered output
        elif kind == "all-to-all":
            link = size * ring
        else:                             # collective-permute
            link = float(size)
        stats.per_chip_link_bytes += link
        stats.raw_bytes += size
        stats.count += 1
        k = stats.by_kind.setdefault(kind, {"count": 0, "link_bytes": 0.0})
        k["count"] += 1
        k["link_bytes"] += link
    return stats
