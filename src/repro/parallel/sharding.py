"""Sharding policy: logical axis names -> mesh PartitionSpecs.

Every parameter / activation in the model zoo is annotated with a tuple of
*logical* axis names (one per dim, ``None`` = replicated).  ``MeshEnv`` maps
logical names onto the physical mesh axes:

  batch            -> all data-parallel axes ("pod","data") / ("data",)
  vocab/heads/ff/
  experts/dinner   -> "model"      (tensor / expert parallelism)
  embed            -> data axes    (FSDP: 2-D weight sharding so params,
                                    grads and optimizer state all scale
                                    with the full chip count)
  kv_heads         -> "model" when the arch's kv-head count divides the TP
                      degree, else replicated (the decode path then uses the
                      sequence-sharded flash-decode cache instead)
  seq_kv           -> "model"      (flash-decode: KV cache sharded on seq)

The env degrades gracefully to single-device smoke-test mode (mesh=None):
constraints become no-ops and shard_map paths fall back to plain jnp.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map across jax versions: new releases expose it at the top
    level with ``check_vma``; 0.4.x has jax.experimental.shard_map with the
    same knob named ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


@dataclasses.dataclass(frozen=True)
class MeshEnv:
    """Physical mesh + the logical->physical axis mapping for one model."""

    mesh: Mesh | None = None
    data_axes: tuple[str, ...] = ("data",)     # DP + FSDP axes (includes "pod")
    model_axis: str | None = "model"
    # per-arch switches, decided from the config at construction time:
    shard_kv_heads: bool = False               # kv_heads % tp == 0
    flash_decode: bool = False                 # seq-shard the decode KV cache
    # Performance knobs (hillclimb levers, see EXPERIMENTS.md #Perf)
    remat: bool = True
    fsdp: bool = True                          # 2-D ("embed"->data) weight sharding

    # ------------------------------------------------------------------ #
    @property
    def tp(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def dp(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def n_devices(self) -> int:
        return 1 if self.mesh is None else self.mesh.size

    # ------------------------------------------------------------------ #
    def _physical(self, logical: str | None):
        if logical is None:
            return None
        if logical == "batch":
            return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        if logical in ("vocab", "heads", "ff", "experts", "dinner", "seq_kv",
                       "seq"):
            # "seq": Megatron-style sequence parallelism — the residual
            # stream between layers is sharded over "model", so saved-for-
            # backward activations scale with the FULL chip count.  XLA
            # inserts the all-gather (into attention/MLP) and reduce-scatter
            # (out of them) this implies.
            return self.model_axis
        if logical == "embed":
            if not self.fsdp:
                return None
            return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        if logical == "kv_heads":
            return self.model_axis if self.shard_kv_heads else None
        if logical == "model":
            return self.model_axis
        if logical == "data":
            return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, axes: tuple[str | None, ...]) -> P:
        return P(*[self._physical(a) for a in axes])

    def _axis_size(self, phys) -> int:
        if phys is None or self.mesh is None:
            return 1
        if isinstance(phys, tuple):
            n = 1
            for a in phys:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[phys]

    def spec_sized(self, axes: tuple[str | None, ...],
                   shape: tuple[int, ...]) -> P:
        """Like spec(), but any dim not divisible by its mesh extent falls
        back to replication (e.g. hymba's 25 heads on TP=16)."""
        phys = []
        for a, dim in zip(axes, shape):
            p = self._physical(a)
            if p is not None and dim % self._axis_size(p) != 0:
                p = None
            phys.append(p)
        return P(*phys)

    def sharding(self, axes: tuple[str | None, ...],
                 shape: tuple[int, ...] | None = None) -> NamedSharding | None:
        if self.mesh is None:
            return None
        spec = self.spec(axes) if shape is None else self.spec_sized(axes, shape)
        return NamedSharding(self.mesh, spec)

    def constrain(self, x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
        """with_sharding_constraint that is a no-op off-mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, self.sharding(axes, tuple(x.shape)))


def logical_to_spec(env: MeshEnv, axes_tree: Any) -> Any:
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: env.spec(axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def param_shardings(env: MeshEnv, axes_tree: Any, sds_tree: Any = None) -> Any:
    """Pytree of NamedShardings (or None off-mesh) mirroring the param tree.

    When sds_tree (shapes) is given, non-divisible dims auto-replicate."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)
    if env.mesh is None:
        return jax.tree.map(lambda _: None, axes_tree, is_leaf=is_axes)
    if sds_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(env.mesh, env.spec(axes)),
            axes_tree, is_leaf=is_axes)
    flat_a, treedef = jax.tree.flatten(axes_tree, is_leaf=is_axes)
    flat_s = treedef.flatten_up_to(sds_tree)
    out = [NamedSharding(env.mesh, env.spec_sized(a, tuple(s.shape)))
           for a, s in zip(flat_a, flat_s)]
    return jax.tree.unflatten(treedef, out)


def make_env(cfg, mesh: Mesh | None, *, multi_pod: bool | None = None,
             fsdp: bool = True, remat: bool = True,
             flash_decode: bool | None = None,
             dp_only: bool = False) -> MeshEnv:
    """Build the MeshEnv for an architecture config on a given mesh.

    dp_only: fold the "model" axis into data parallelism (batch sharded over
    every mesh axis, params replicated/FSDP).  The right choice for small
    models (whisper-medium at TP=16 is collective-bound — EXPERIMENTS.md
    #Perf iteration W1)."""
    if mesh is None:
        return MeshEnv(mesh=None, data_axes=("data",), model_axis=None,
                       shard_kv_heads=False, flash_decode=False,
                       remat=remat, fsdp=False)
    names = mesh.axis_names
    if dp_only:
        return MeshEnv(mesh=mesh, data_axes=tuple(names), model_axis=None,
                       shard_kv_heads=False, flash_decode=False,
                       remat=remat, fsdp=fsdp)
    data_axes = tuple(a for a in names if a in ("pod", "data"))
    model_axis = "model" if "model" in names else None
    tp = mesh.shape[model_axis] if model_axis else 1
    n_kv = getattr(cfg, "n_kv", 0) or 0
    shard_kv = n_kv > 0 and tp > 0 and (n_kv % tp == 0)
    if flash_decode is None:
        # default: flash-decode whenever the kv heads don't divide TP
        flash_decode = (n_kv > 0) and not shard_kv
    return MeshEnv(mesh=mesh, data_axes=data_axes, model_axis=model_axis,
                   shard_kv_heads=shard_kv, flash_decode=flash_decode,
                   remat=remat, fsdp=fsdp)
