#!/usr/bin/env python
"""Docs gate (CI `docs-check`): keep the prose as tested as the code.

Three checks over README.md and docs/*.md:

1. every fenced ```python snippet must at least *compile* — docs with
   syntax errors teach broken idiom;
2. every relative markdown link must resolve to a file or directory in
   the repo — stale paths are how docs rot;
3. every registered backend name must appear in docs/backends.md — the
   authoring guide's table is the user-facing backend inventory, and a
   backend that ships undocumented fails the build.

Run it the way CI does:

    PYTHONPATH=src python tools/check_docs.py

Exit 0 when clean; exit 1 with one line per problem otherwise.  The
check functions are imported by tests/test_docs.py, so the gate also
runs in the tier-1 suite.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

_FENCE_RE = re.compile(r"^```(\w*)\s*$")
# [text](target) — target up to the first ')' or whitespace; images share
# the syntax, so they are covered too
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def doc_files() -> list[Path]:
    return [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]


def python_snippets(text: str) -> list[tuple[int, str]]:
    """(first line number, source) per fenced ```python block."""
    out, lang, buf, start = [], None, [], 0
    for i, line in enumerate(text.splitlines(), 1):
        m = _FENCE_RE.match(line)
        if m and lang is None:
            lang, buf, start = m.group(1), [], i + 1
        elif line.strip() == "```" and lang is not None:
            if lang == "python":
                out.append((start, "\n".join(buf)))
            lang = None
        elif lang is not None:
            buf.append(line)
    return out


def check_snippets(path: Path) -> list[str]:
    errors = []
    for lineno, code in python_snippets(path.read_text()):
        try:
            compile(code, f"{path.name}:{lineno}", "exec")
        except SyntaxError as e:
            errors.append(
                f"{path.relative_to(ROOT)}:{lineno}: python snippet does "
                f"not compile: {e.msg} (snippet line {e.lineno})")
    return errors


def check_links(path: Path) -> list[str]:
    errors = []
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        for target in _LINK_RE.findall(line):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            if not resolved.exists():
                errors.append(
                    f"{path.relative_to(ROOT)}:{lineno}: broken relative "
                    f"link {target!r}")
    return errors


def check_backend_coverage() -> list[str]:
    """Every *built-in* registered backend must be named in
    docs/backends.md.  Built-in = factory defined under the repro
    package, so fixture backends registered by a test process don't
    trip the gate."""
    from repro.backends import get_backend, list_backends
    text = (ROOT / "docs" / "backends.md").read_text()
    errors = []
    for name in list_backends():
        if not get_backend(name).factory.__module__.startswith("repro."):
            continue
        if f"`{name}`" not in text:
            errors.append(
                f"docs/backends.md: registered backend `{name}` is "
                "undocumented — add it to the built-in families table")
    return errors


def run_all() -> list[str]:
    errors = []
    for path in doc_files():
        errors += check_snippets(path)
        errors += check_links(path)
    errors += check_backend_coverage()
    return errors


def main() -> int:
    errors = run_all()
    for e in errors:
        print(e, file=sys.stderr)
    n_docs = len(doc_files())
    if not errors:
        print(f"docs-check: {n_docs} files clean")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
