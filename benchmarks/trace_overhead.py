"""Recorder overhead: traced vs untraced sweep on the same simulated device.

The trace subsystem's contract is *bounded* overhead — recording must be
cheap enough to leave on in production measurement runs.  Two identical
devices (same seed, same RNG stream) run the same phase-2 switch passes;
one is wrapped in :class:`repro.trace.TracedBackend`.  Since the simulated
work is deterministic and identical, the wall-clock ratio isolates the
recording cost: compact uint16 duration-tick retention, pre-faulted
arenas, folded sync rounds, payload-free warm-ups.

Acceptance bar: overhead < 5% (``OVERHEAD_BAR_PCT``).  The strict bar is
enforced by the CI ``trace-smoke`` job from the emitted
``BENCH_trace.json`` on standardized runners; the in-bench assertion uses
``OVERHEAD_SANITY_PCT`` so a genuinely regressed design (e.g. retaining
device buffers, which measured 46%) still fails anywhere, while a
memory-starved container (no THP, ~1 GB/s first-touch) doesn't flag the
recorder for the host's page-fault costs.

  PYTHONPATH=src python -m benchmarks.run --only trace
"""
from __future__ import annotations

import time

import numpy as np

OVERHEAD_BAR_PCT = 5.0       # the design bar, gated in CI
OVERHEAD_SANITY_PCT = 20.0   # asserted every run, any hardware
_FREQS = [210.0, 705.0, 1410.0]
_N_CORES = 72          # paper-scale device (RTX Quadro 6000: 72 SMs); at
                       # toy core counts the per-pass fixed costs dominate
                       # and the ratio stops measuring the recorder design
_PASSES = 3
_REPEATS = 8


def _make(seed: int = 0):
    from repro.backends import create_backend
    return create_backend("simulated", kind="a100", n_cores=_N_CORES,
                          seed=seed)


def _calibrated(device):
    from repro.core.calibration import calibrate
    from repro.core.workload import WorkloadSpec
    spec = WorkloadSpec(iters_per_kernel=900, flops_per_iter=40e-6,
                        delay_iters=250, confirm_iters=300)
    return calibrate(device, _FREQS, spec), spec


def _sweep_interleaved(arms):
    """One round of phase-2 switch passes over every pair, alternating
    between the measurement arms *within* each pair so both arms see the
    same machine state; returns one wall-time column per arm."""
    from repro.core.switching import measure_switch_once
    times = [[] for _ in arms]
    for fi in _FREQS:
        for ft in _FREQS:
            if fi == ft:
                continue
            for col, (device, cal, spec) in zip(times, arms):
                t0 = time.perf_counter()
                for _ in range(_PASSES):
                    measure_switch_once(device, fi, ft, cal, spec)
                device.throttle_reasons()
                col.append(time.perf_counter() - t0)
    return [np.asarray(col) for col in times]


def bench_trace():
    """Yields (name, us_per_call, derived) rows for benchmarks.run; the
    emitted record is BENCH_trace.json."""
    import tempfile

    from repro.core.paths import results_dir
    from repro.trace.recorder import Trace, TracedBackend, TraceRecorder

    # identical seeds -> identical RNG streams -> identical numpy work.
    # Arms are interleaved per frequency pair and reduced with an
    # elementwise minimum over rounds: per-pair floors converge to the
    # noise-free cost, while a sequential A-then-B wall-clock comparison
    # is easily off by 2x on a contended box.
    plain_dev = _make(seed=0)
    plain = (plain_dev, *_calibrated(plain_dev))
    recorder = TraceRecorder()
    traced_dev = TracedBackend(_make(seed=0), recorder)
    traced = (traced_dev, *_calibrated(traced_dev))
    # flight-recorder style: pre-touch the arenas for the whole run so the
    # timed region measures the recorder, not the kernel's page-fault path
    n_pairs = len(_FREQS) * (len(_FREQS) - 1)
    n_passes = n_pairs * _PASSES * _REPEATS
    recorder.prefault(
        wait_samples=n_passes * _N_CORES * traced[2].iters_per_kernel,
        sync_exchanges=n_passes * 16)
    plain_t = traced_t = None
    for _ in range(_REPEATS):
        p, t = _sweep_interleaved([plain, traced])
        plain_t = p if plain_t is None else np.minimum(plain_t, p)
        traced_t = t if traced_t is None else np.minimum(traced_t, t)
    plain_s, traced_s = float(plain_t.sum()), float(traced_t.sum())
    overhead_pct = 100.0 * (traced_s - plain_s) / plain_s
    assert overhead_pct < OVERHEAD_SANITY_PCT, (
        f"recorder overhead {overhead_pct:.2f}% exceeds even the "
        f"{OVERHEAD_SANITY_PCT}% sanity bound — the recorder design "
        "regressed (page-fault noise alone cannot explain this)")
    yield ("trace_record", traced_s * 1e6,
           f"overhead={overhead_pct:.2f}% vs untraced "
           f"(bar <{OVERHEAD_BAR_PCT}% on standardized runners) "
           f"n_events={recorder.n_events}")

    # persistence round-trip: save + load + payload integrity
    out = tempfile.mkdtemp(prefix="overhead_",
                           dir=results_dir("trace", create=True))
    t0 = time.perf_counter()
    trace = recorder.save(out)
    save_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    loaded = Trace.load(out)
    load_s = time.perf_counter() - t0
    np.testing.assert_array_equal(loaded.payload, trace.payload)
    yield ("trace_save", save_s * 1e6,
           f"events={trace.n_events} payload_rows={trace.payload.shape[0]}")
    yield ("trace_load", load_s * 1e6, "round-trip bit-identical")


if __name__ == "__main__":
    for name, us, derived in bench_trace():
        print(f"{name},{us:.1f},{derived}")
