"""Cluster dispatch benchmark: multi-node campaigns under chaos.

Runs the same simulated fleet three ways — serial (the paper's
single-host shape), ``--executor processes`` (the in-host work queue),
and ``--executor cluster`` (simulated nodes over the chaos-injectable
transport with all store traffic retry-wrapped) — into separate stores,
verifies the cluster store is **bit-identical** to serial via the
campaign content digest, and records two numbers in
``BENCH_cluster.json``:

* **dispatch overhead**: cluster wall time relative to the process
  executor on the identical fleet — what the transport hop, the remote
  store round trips, and the driver loop cost on top of plain process
  dispatch;
* **recovery time**: with ``--inject-crash``, the scheduler's
  ``recovery_s`` stat — worker-loss detection to the requeued unit's
  completion (the resumed attempt restarts from the store's uploaded
  pair files, so this bounds the blast radius of losing a node).

CI's ``distributed-smoke`` job runs ``--smoke --inject-crash
--inject-partition``: a node dies two pairs into a unit AND the driver's
store link partitions for a window of operations; the campaign must
still complete within the attempt budget with the merged store
bit-identical to serial.

  PYTHONPATH=src python -m benchmarks.cluster_dispatch [--smoke]
      [--nodes N] [--inject-crash] [--inject-partition] [--units N]
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import time

_KIND = "gh200"
_FREQS = (345.0, 1155.0, 1980.0)


def fleet_spec(n_units: int, *, n_cores: int, max_measurements: int,
               retries: int = 3):
    from repro.campaign import CampaignSpec, DeviceSpec, MeasureSpec
    measure = MeasureSpec(key="fast", min_measurements=4,
                          max_measurements=max_measurements,
                          rse_check_every=4)
    devices = tuple(
        DeviceSpec.make(f"u{i:02d}-{_KIND}", "simulated",
                        {"kind": _KIND, "n_cores": n_cores, "seed": i},
                        frequencies=_FREQS)
        for i in range(n_units))
    return CampaignSpec("cluster-dispatch", devices=devices,
                        measures=(measure,), retries=retries)


def crash_unit_key(spec) -> str:
    return spec.units()[0].key


def run_cluster_bench(*, n_units: int, n_cores: int, max_measurements: int,
                      nodes: int, inject_crash: bool, inject_partition: bool,
                      store_root: str, verbose: bool = False):
    """Serial reference, process baseline, cluster candidate; returns
    (rows, cluster stats, metrics)."""
    from repro.campaign import ArtifactStore, CampaignRunner
    from repro.campaign.workqueue import FaultPlan, fault_marker_path

    spec = fleet_spec(n_units, n_cores=n_cores,
                      max_measurements=max_measurements)
    roots = {name: os.path.join(store_root, name)
             for name in ("serial", "processes", "cluster")}
    for r in roots.values():            # fresh stores: measure, not resume
        shutil.rmtree(r, ignore_errors=True)

    t0 = time.perf_counter()
    ref = CampaignRunner(spec, ArtifactStore(roots["serial"])).run(
        verbose=verbose)
    t_serial = time.perf_counter() - t0
    if not ref.ok:
        raise AssertionError(f"serial reference failed: "
                             f"{[(o.key, o.error) for o in ref.failed()]}")

    t0 = time.perf_counter()
    proc = CampaignRunner(spec, ArtifactStore(roots["processes"]),
                          executor="processes", max_workers=nodes).run(
        verbose=verbose)
    t_proc = time.perf_counter() - t0
    if not proc.ok:
        raise AssertionError(f"process baseline failed: "
                             f"{[(o.key, o.error) for o in proc.failed()]}")

    faults = {}
    if inject_crash:
        faults["node_crash_after_pairs"] = {crash_unit_key(spec): 2}
    if inject_partition:
        # the driver's first marks ride, then a window of its store ops
        # fails until the retries spend it — heals within one backoff cycle
        faults["store_partition"] = (2, 4)
    plan = FaultPlan.make(**faults) if faults else None

    t0 = time.perf_counter()
    cand = CampaignRunner(spec, ArtifactStore(roots["cluster"]),
                          executor="cluster", max_workers=nodes,
                          fault_plan=plan).run(verbose=verbose)
    t_cluster = time.perf_counter() - t0
    if not cand.ok:
        raise AssertionError(f"cluster campaign failed: "
                             f"{[(o.key, o.error) for o in cand.failed()]}")

    recovery_s = float(cand.stats.get("recovery_s", 0.0))
    if inject_crash:
        marker = fault_marker_path(cand.campaign, crash_unit_key(spec),
                                   "node_crash")
        if not os.path.exists(marker):
            raise AssertionError(
                f"injected node crash never fired (missing {marker})")
        if cand.stats.get("crashed_nodes", 0) < 1:
            raise AssertionError(
                f"crash fired but no node was reaped: {cand.stats}")
        if cand.stats.get("requeued_units", 0) < 1:
            raise AssertionError(
                f"crashed unit was not requeued: {cand.stats}")
        if recovery_s <= 0:
            raise AssertionError(
                f"no recovery time recorded after a node kill: {cand.stats}")
    if inject_partition and cand.stats.get("driver_partitioned_ops", 0) < 1:
        raise AssertionError(
            f"injected partition never fired: {cand.stats}")

    ref_digest = ref.campaign.content_digest()
    if cand.campaign.content_digest() != ref_digest:
        raise AssertionError(
            "cluster store is NOT bit-identical to the serial reference")
    n_units_done = len(cand.campaign.done_units())

    overhead = t_cluster / t_proc if t_proc > 0 else float("inf")
    chaos = "+".join(n for n, on in (("crash", inject_crash),
                                     ("partition", inject_partition)) if on)
    rows = [
        ("cluster_serial_ref", t_serial * 1e6,
         f"units={n_units} wall_s={t_serial:.2f}"),
        ("cluster_process_baseline", t_proc * 1e6,
         f"workers={nodes} wall_s={t_proc:.2f}"),
        ("cluster_dispatch", t_cluster * 1e6,
         f"nodes={nodes} wall_s={t_cluster:.2f} "
         f"dispatch_overhead_vs_processes={overhead:.2f} "
         f"recovery_s={recovery_s:.3f} "
         f"bit_identical_units={n_units_done}"
         + (f" chaos={chaos}" if chaos else "")),
    ]
    metrics = {"t_serial": t_serial, "t_proc": t_proc,
               "t_cluster": t_cluster, "overhead": overhead,
               "recovery_s": recovery_s, "digest": ref_digest}
    return rows, cand.stats, metrics


def bench_cluster():
    """benchmarks.run entry point -> BENCH_cluster.json."""
    from repro.core.paths import results_dir
    rows, _, metrics = run_cluster_bench(
        n_units=6, n_cores=8, max_measurements=8,
        nodes=min(3, os.cpu_count() or 1), inject_crash=True,
        inject_partition=False,
        store_root=results_dir("cluster-dispatch"))
    # sanity ceiling only: node threads share the GIL, so the cluster sim
    # trades wall time for fault coverage; a blown ceiling means the
    # dispatch loop or retry layer regressed pathologically
    assert metrics["overhead"] < 6.0, (
        f"cluster dispatch overhead {metrics['overhead']:.2f}x over the "
        "process executor")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fleet (4 small units)")
    ap.add_argument("--nodes", type=int,
                    default=min(3, os.cpu_count() or 1))
    ap.add_argument("--units", type=int, default=None,
                    help="fleet size (default: 4 smoke / 6 full)")
    ap.add_argument("--inject-crash", action="store_true",
                    help="kill a node two pairs into a unit; the run must "
                         "complete via requeue with recovery_s recorded")
    ap.add_argument("--inject-partition", action="store_true",
                    help="partition the driver from the store for a window "
                         "of operations; the retry layer must ride it out")
    ap.add_argument("--store-root", default=None,
                    help="scratch store root (default: "
                         "$REPRO_RESULTS_DIR/cluster-dispatch)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.core.paths import results_dir
    n_units = args.units or (4 if args.smoke else 6)
    shape = (dict(n_cores=6, max_measurements=6) if args.smoke
             else dict(n_cores=8, max_measurements=8))
    rows, stats, metrics = run_cluster_bench(
        n_units=n_units, nodes=args.nodes,
        inject_crash=args.inject_crash,
        inject_partition=args.inject_partition,
        store_root=args.store_root or results_dir("cluster-dispatch"),
        verbose=args.verbose, **shape)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"cluster stats: {stats}", file=sys.stderr)

    from benchmarks.run import _emit_json
    _emit_json(results_dir("bench"), "cluster", rows,
               sum(us for _, us, _ in rows) / 1e6)
    print(f"ok: bit-identical to serial, "
          f"{metrics['overhead']:.2f}x dispatch overhead vs processes"
          + (f", {metrics['recovery_s']:.2f}s node-kill recovery"
             if args.inject_crash else "")
          + "; BENCH_cluster.json written")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
