"""Analysis-engine benchmark: sorted-window DBSCAN + prefix-sum silhouette
vs the O(n²) matrix reference on one measured pair's latency samples.

Phase-3 filtering (Alg. 3 adaptive DBSCAN + §VII-B silhouette) runs on
every sweep, every campaign aggregation and every ``diff_campaigns`` gate,
so its cost scales with the fleet.  The sorted engine is O(n log n) / O(n)
memory and must agree with the reference exactly: cluster labels
bit-identical, silhouette within 1e-12 (prefix sums reorder additions, so
bit-identity is not expected there).  Both properties are ASSERTED here on
every run — the benchmark doubles as the fast-vs-reference smoke check CI
executes on a small input.

Acceptance bar (5k-sample pair): combined speedup >= 30x.

  PYTHONPATH=src python -m benchmarks.analysis_speedup [--n 5000]

writes ``BENCH_analysis.json`` under ``$REPRO_RESULTS_DIR/bench`` (also
emitted by ``python -m benchmarks.run``).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.dbscan import adaptive_dbscan
from repro.core.silhouette import silhouette_score

N_SAMPLES = 5000
FAST_REPS = 5


def _pair_samples(n: int, seed: int = 0) -> np.ndarray:
    """A realistic measured pair at fleet scale: two latency clusters
    (Figs. 5-6's multi-modal shape) plus a few percent of far outliers."""
    rng = np.random.default_rng(seed)
    n_out = max(1, n // 50)
    n_hi = n // 4
    n_lo = n - n_hi - n_out
    return rng.permutation(np.concatenate([
        rng.normal(12e-3, 0.4e-3, n_lo),
        rng.normal(27e-3, 0.6e-3, n_hi),
        rng.uniform(80e-3, 400e-3, n_out),
    ]))


def _timed(fn, reps: int):
    fn()                                       # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    return out, (time.perf_counter() - t0) / reps


def bench_analysis(n: int = N_SAMPLES):
    x = _pair_samples(n)

    ref, ref_db_s = _timed(lambda: adaptive_dbscan(x, impl="matrix"), 1)
    ref_sil, ref_sil_s = _timed(
        lambda: silhouette_score(x, ref.labels, impl="matrix"), 1)
    fast, fast_db_s = _timed(lambda: adaptive_dbscan(x), FAST_REPS)
    fast_sil, fast_sil_s = _timed(
        lambda: silhouette_score(x, fast.labels), FAST_REPS)

    if not np.array_equal(fast.labels, ref.labels):
        raise AssertionError(
            f"sorted DBSCAN labels diverge from matrix reference on "
            f"n={n}: {int((fast.labels != ref.labels).sum())} mismatches")
    if (fast.min_pts, fast.eps) != (ref.min_pts, ref.eps):
        raise AssertionError("adaptive sweep picked different parameters")
    sil_err = (0.0 if np.isnan(fast_sil) and np.isnan(ref_sil)
               else abs(fast_sil - ref_sil))
    if not sil_err <= 1e-12:
        raise AssertionError(
            f"silhouette mismatch: fast={fast_sil!r} ref={ref_sil!r}")

    total = (ref_db_s + ref_sil_s) / (fast_db_s + fast_sil_s)
    return [
        ("analysis/adaptive_dbscan", fast_db_s * 1e6,
         f"speedup={ref_db_s / fast_db_s:.1f}x n={n} "
         f"identical_labels=True"),
        ("analysis/silhouette", fast_sil_s * 1e6,
         f"speedup={ref_sil_s / fast_sil_s:.1f}x n={n} "
         f"max_err={sil_err:.1e}"),
        ("analysis/engine", (fast_db_s + fast_sil_s) * 1e6,
         f"speedup={total:.1f}x n={n} "
         f"ref_ms={(ref_db_s + ref_sil_s) * 1e3:.0f}"),
    ]


def main() -> None:
    import argparse

    from repro.core.paths import results_dir

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=N_SAMPLES,
                    help="samples in the synthetic pair (default 5000)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    rows = bench_analysis(args.n)              # raises on any disagreement
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    from benchmarks.run import _emit_json
    _emit_json(results_dir("bench"), "analysis", rows,
               time.perf_counter() - t0)
    print(f"wrote BENCH_analysis.json ({len(rows)} rows)")


if __name__ == "__main__":
    main()
