"""Roofline table from the dry-run sweep artifacts (deliverable g)."""
from __future__ import annotations

import json
import os

from repro.configs import ARCH_IDS, SHAPES
from repro.core.paths import results_dir


def load_cells(out_dir=None, mesh="single", suffix=""):
    out_dir = out_dir if out_dir is not None else results_dir("dryrun")
    cells = {}
    for arch in ARCH_IDS:
        for shape in SHAPES:
            tag = f"{arch}__{shape}__{mesh}{suffix}"
            p = os.path.join(out_dir, tag + ".json")
            if os.path.exists(p):
                cells[(arch, shape)] = json.load(open(p))
    return cells


def bench_roofline_table():
    rows = []
    for mesh in ("single", "multi"):
        cells = load_cells(mesh=mesh)
        n_ok = sum(1 for c in cells.values() if c["status"] == "ok")
        n_skip = sum(1 for c in cells.values() if c["status"] == "skipped")
        rows.append((f"dryrun/{mesh}", 0.0,
                     f"cells={len(cells)} ok={n_ok} skipped={n_skip} "
                     f"errors={len(cells)-n_ok-n_skip}"))
    cells = load_cells(mesh="single")
    for (arch, shape), c in sorted(cells.items()):
        if c["status"] != "ok":
            rows.append((f"roofline/{arch}/{shape}", 0.0,
                         f"SKIPPED: {c.get('reason','')[:60]}"))
            continue
        r = c["roofline"]
        rows.append((
            f"roofline/{arch}/{shape}",
            (c.get("lower_s", 0) + c.get("compile_s", 0)) * 1e6,
            f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
            f"collective={r['collective_s']*1e3:.2f}ms dom={r['dominant']} "
            f"mfu={r['mfu_roofline']:.3f} model/hlo={r['model_flops_ratio']:.2f}"))
    return rows
