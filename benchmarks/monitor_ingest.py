"""Fleet-monitor benchmark: ingest throughput + drift-detection delay.

Three campaigns of the same simulated unit feed one
:class:`~repro.monitor.service.MonitorService` baseline:

* **baseline** — measured with ``trace=True``; its tables are what the
  monitor watches.
* **stationary** — identical unit physics, different measurement seed.
  Replaying its stream against the baseline must raise ZERO alerts (the
  false-positive gate) and times the ingest path (events/sec).
* **drifted** — run through the process scheduler with a
  :class:`~repro.campaign.workqueue.FaultPlan` ``drift_after_pairs``
  injection: after two measured pairs the unit's live transition model is
  silently scaled 4x.  Replaying its stream must alert within the
  documented sample budget, only on pairs the batch differ
  (``diff_campaigns``) also flags on the same tables, and a second replay
  must reproduce bit-identical alert artifacts.

Writes ``BENCH_monitor.json`` rows plus a ``monitor-smoke.json`` manifest
(campaign id, trace directories, flagged pairs) that CI's
``monitor-smoke`` job feeds to ``python -m repro.monitor replay``.

  PYTHONPATH=src python -m benchmarks.monitor_ingest [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import time

# Every alert must land within this many samples of the pair's drifted
# stream starting — the acceptance budget the README documents.  The
# monitor needs min_window=4 samples before a confirm may run, so the
# floor is 4; 8 leaves headroom for a noisy first window without letting
# detection drag a whole second sweep.
DETECT_BUDGET_SAMPLES = 8
DRIFT_SCALE = 4.0
DRIFT_AFTER_PAIRS = 2


def unit_spec(name: str, *, seed: int, n_freqs: int, max_measurements: int):
    """One vmapped-sim gh200 unit; ``seed`` varies only the measurement
    noise — unit physics (unit_seed) stay fixed across all campaigns."""
    from repro.campaign import CampaignSpec, DeviceSpec, MeasureSpec
    measure = MeasureSpec(key="fast", min_measurements=6,
                          max_measurements=max_measurements,
                          rse_check_every=6)
    dev = DeviceSpec.make("gh200", "vmapped-sim",
                          {"kind": "gh200", "n_cores": 6, "seed": seed,
                           "unit_seed": 0}, n_freqs=n_freqs)
    return CampaignSpec(name, devices=(dev,), measures=(measure,))


def _run(spec, store, **kw):
    from repro.campaign import CampaignRunner
    result = CampaignRunner(spec, store, trace=True, **kw).run(verbose=False)
    if not result.ok:
        raise AssertionError(
            f"{spec.name} failed: {[(o.key, o.error) for o in result.failed()]}")
    return result


def _timed_replay(baseline_campaign, trace, *, window, heartbeat_timeout_s):
    """Fresh monitor, one trace replayed; returns (service, alerts, wall_s)."""
    from repro.monitor import DriftConfig, MonitorConfig, MonitorService
    service = MonitorService(
        baseline_campaign,
        MonitorConfig(drift=DriftConfig(window=window),
                      heartbeat_timeout_s=heartbeat_timeout_s))
    t0 = time.perf_counter()
    alerts = service.replay_trace(trace)
    return service, alerts, time.perf_counter() - t0


def run_monitor_bench(*, n_freqs: int, max_measurements: int,
                      store_root: str, manifest_out: str | None = None,
                      fresh: bool = True):
    """Returns (rows, manifest) — rows feed BENCH_monitor.json."""
    from repro.campaign import ArtifactStore, diff_campaigns
    from repro.campaign.workqueue import FaultPlan, fault_marker_path

    if fresh:
        shutil.rmtree(store_root, ignore_errors=True)
    store = ArtifactStore(store_root)

    shape = dict(n_freqs=n_freqs, max_measurements=max_measurements)
    base_spec = unit_spec("monitor-baseline", seed=0, **shape)
    unit_key = base_spec.units()[0].key
    baseline = _run(base_spec, store)

    stationary = _run(unit_spec("monitor-stationary", seed=1, **shape), store)
    drift_spec = unit_spec("monitor-drifted", seed=2, **shape)
    drifted = _run(
        drift_spec, store, executor="processes", max_workers=1,
        fault_plan=FaultPlan.make(drift_after_pairs={
            unit_key: (DRIFT_AFTER_PAIRS, DRIFT_SCALE)}))
    marker = fault_marker_path(drifted.campaign, unit_key, "drift")
    if not os.path.exists(marker):
        raise AssertionError(
            f"drift injection never fired (missing {marker}) — the "
            "detection numbers below would prove nothing")

    # stale detection is stream-relative; a single replayed device never
    # goes silent against itself, but keep the timeout out of the way
    hb = 1e9
    window = 32

    # -- false-positive gate + ingest throughput (stationary stream) ----
    flat_trace = stationary.campaign.load_trace(unit_key)
    service, false_alerts, wall_flat = _timed_replay(
        baseline.campaign, flat_trace, window=window, heartbeat_timeout_s=hb)
    if false_alerts:
        raise AssertionError(
            "stationary replay raised alerts (false positives): "
            f"{[doc['kind'] for _, _, doc in false_alerts]}")
    flat_diff = diff_campaigns(baseline.campaign, stationary.campaign)
    if not flat_diff.clean:
        raise AssertionError(
            "batch differ flagged the stationary campaign — the two "
            "measurement seeds are not drift-free; pick different seeds")
    flat_status = service.status()["devices"][service.devices[0]]
    n_events = flat_status["events"]

    # -- must-detect gate (drifted stream) ------------------------------
    drift_trace = drifted.campaign.load_trace(unit_key)
    service_d, alerts, wall_drift = _timed_replay(
        baseline.campaign, drift_trace, window=window, heartbeat_timeout_s=hb)
    drift_alerts = [doc for _, _, doc in alerts if doc["kind"] == "drift"]
    if not drift_alerts:
        raise AssertionError("injected 4x drift raised no alert")
    delay = min(doc["sample_index"] for doc in drift_alerts)
    if delay > DETECT_BUDGET_SAMPLES:
        raise AssertionError(
            f"detection took {delay} samples "
            f"(budget {DETECT_BUDGET_SAMPLES})")

    # -- batch agreement: every streamed alert pair is also flagged by
    # diff_campaigns on the full tables (same rule, batch-wise) ---------
    batch = diff_campaigns(baseline.campaign, drifted.campaign)
    flagged = {(d.f_init, d.f_target) for d in batch.flagged()}
    streamed = {(doc["f_init"], doc["f_target"]) for doc in drift_alerts}
    if not streamed <= flagged:
        raise AssertionError(
            f"streaming alerted pairs {sorted(streamed - flagged)} the "
            "batch differ does not flag — the verdicts diverged")

    # -- determinism: re-replay reproduces bit-identical artifacts ------
    _, alerts2, _ = _timed_replay(
        baseline.campaign, drift_trace, window=window, heartbeat_timeout_s=hb)
    ids, ids2 = [a for a, _, _ in alerts], [a for a, _, _ in alerts2]
    if ids != ids2:
        raise AssertionError(
            f"re-replay changed the alert ids: {ids} vs {ids2}")

    n_events_d = service_d.status()["devices"][service_d.devices[0]]["events"]
    rate = n_events / wall_flat if wall_flat > 0 else float("inf")
    rows = [
        ("monitor_ingest", wall_flat / max(n_events, 1) * 1e6,
         f"events={n_events} events_per_s={rate:.0f} "
         f"passes={flat_status['passes']} false_alerts=0"),
        ("monitor_detect", wall_drift / max(n_events_d, 1) * 1e6,
         f"detect_delay_samples={delay} budget={DETECT_BUDGET_SAMPLES} "
         f"alerts={len(drift_alerts)} flagged_pairs={len(flagged)} "
         f"batch_agree=1 replay_bit_identical=1"),
    ]
    manifest = {
        "store": store_root,
        "baseline": baseline.campaign.campaign_id,
        "stationary": stationary.campaign.campaign_id,
        "drifted": drifted.campaign.campaign_id,
        "unit_key": unit_key,
        "no_drift_trace": stationary.campaign.trace_path(unit_key, "session"),
        "drift_trace": drifted.campaign.trace_path(unit_key, "session"),
        "flagged_pairs": sorted(flagged),
        "detect_delay_samples": delay,
        "detect_budget_samples": DETECT_BUDGET_SAMPLES,
    }
    if manifest_out:
        os.makedirs(os.path.dirname(manifest_out) or ".", exist_ok=True)
        with open(manifest_out, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
            f.write("\n")
    return rows, manifest


def bench_monitor():
    """benchmarks.run entry point -> BENCH_monitor.json."""
    from repro.core.paths import results_dir
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    shape = (dict(n_freqs=3, max_measurements=8) if smoke
             else dict(n_freqs=4, max_measurements=10))
    rows, _ = run_monitor_bench(
        store_root=results_dir("monitor-bench"),
        manifest_out=os.path.join(results_dir("bench"),
                                  "monitor-smoke.json"), **shape)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (3 freqs, 8 measurements)")
    ap.add_argument("--store-root", default=None,
                    help="scratch store root (default: "
                         "$REPRO_RESULTS_DIR/monitor-bench)")
    ap.add_argument("--manifest-out", default=None,
                    help="write the monitor-smoke.json manifest here "
                         "(default: $REPRO_RESULTS_DIR/bench/)")
    args = ap.parse_args(argv)

    from repro.core.paths import results_dir
    shape = (dict(n_freqs=3, max_measurements=8) if args.smoke
             else dict(n_freqs=4, max_measurements=10))
    rows, manifest = run_monitor_bench(
        store_root=args.store_root or results_dir("monitor-bench"),
        manifest_out=args.manifest_out or os.path.join(
            results_dir("bench"), "monitor-smoke.json"), **shape)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    from benchmarks.run import _emit_json
    _emit_json(results_dir("bench"), "monitor", rows,
               sum(us for _, us, _ in rows) / 1e6)
    print(f"manifest: baseline={manifest['baseline']} "
          f"detect_delay={manifest['detect_delay_samples']} "
          f"(budget {manifest['detect_budget_samples']})", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
