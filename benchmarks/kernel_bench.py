"""Kernel micro-benchmarks (interpret-mode correctness + XLA-path timing —
wall times on this CPU container are for harness completeness, not TPU
performance claims; TPU numbers come from the roofline terms)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.microbench import microbench, microbench_ref
from repro.kernels.microbench.ops import flops_per_core, make_input
from repro.kernels.ssd.ops import ssd_pallas
from repro.models import layers
from repro.models.ssm import ssd_ref


def bench_microbench_kernel():
    x = make_input(16)
    out, us = timed(lambda: jax.block_until_ready(
        microbench(x, n_iters=32, unroll=16)))
    ref = microbench_ref(x, n_iters=32, unroll=16)
    err = float(jnp.abs(out - ref).max())
    fl = flops_per_core(32, 16) * 16
    return [("kernel/microbench", us,
             f"cores=16 flops={fl:.2e} allclose_err={err:.1e}")]


def bench_flash_attention_kernel():
    ks = [jax.random.PRNGKey(i) for i in range(3)]
    b, s, h, kv, dh = 1, 256, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    out, us = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, blk_q=64, blk_k=64)))
    ref = flash_attention_ref(q, k, v)
    err = float(jnp.abs(out - ref).max())
    return [("kernel/flash_attention", us,
             f"s={s} gqa={h}/{kv} allclose_err={err:.1e}")]


def bench_ssd_kernel():
    ks = [jax.random.PRNGKey(i) for i in range(5)]
    b, l, h, p, n, chunk = 1, 256, 4, 16, 32, 64
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, n))
    C = jax.random.normal(ks[4], (b, l, n))
    (y1, h1), us = timed(lambda: jax.tree.map(
        jax.block_until_ready, ssd_pallas(x, dt, A, B, C, chunk)))
    y2, h2 = ssd_ref(x, dt, A, B, C, chunk)
    err = float(jnp.abs(y1 - y2).max())
    return [("kernel/ssd", us, f"l={l} chunk={chunk} allclose_err={err:.1e}")]


def bench_xla_attention_paths():
    """chunked (flash-VJP) vs triangular prefill vs naive, one mid shape."""
    ks = [jax.random.PRNGKey(i) for i in range(3)]
    b, s, h, kv, dh = 2, 512, 8, 4, 64
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, kv, dh), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, kv, dh), jnp.bfloat16)
    rows = []
    for name, fn in [
        ("naive", lambda: layers.naive_attention(q, k, v)),
        ("chunked", lambda: layers.chunked_attention(q, k, v, kv_chunk=128)),
        ("prefill_tri", lambda: layers.prefill_attention(q, k, v, kv_chunk=128)),
    ]:
        jitted = jax.jit(fn)
        jax.block_until_ready(jitted())           # compile
        _, us = timed(lambda: jax.block_until_ready(jitted()))
        rows.append((f"attention/{name}", us, f"s={s} bf16"))
    return rows
