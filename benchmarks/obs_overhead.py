"""Span-profiler overhead benchmark: spans-on vs spans-off campaigns.

The ``repro.obs`` contract is that profiling must *observe* a campaign
without perturbing it.  This benchmark runs the same simulated fleet
through the cluster executor twice on a clean network — spans off, then
spans on — plus a serial single-host reference, and hard-asserts:

* **bit-identity**: every store (spans on, spans off, chaos) has the
  same content digest as the serial reference — span files live outside
  the digest by construction, and recording must not reorder or reseed
  anything that lands in a measurement artifact;
* **export validity**: the merged span rows export to Chrome
  ``trace_event`` JSON that passes ``validate_trace_events`` (the same
  document ui.perfetto.dev loads);
* **profile coherence**: the critical-path analyzer names a dominant
  cost and its segments tile the campaign root exactly.

Recorded numbers (``BENCH_obs.json``):

* **span overhead**: spans-on wall time relative to spans-off, as
  ``overhead=X%`` in the derived string — CI's ``profile-smoke`` job
  gates this under 5% (best of two: hosted runners are multi-tenant and
  noise only ever inflates the observed overhead);
* **profile analysis cost**: wall time of ``profile_campaign`` over the
  recorded rows.

``--inject-crash`` / ``--inject-partition`` add a fourth, chaos run
(node kill + driver<->store partition, spans ON) whose store must still
be bit-identical — proving the recorder survives requeue/speculation
paths, not just clean runs.

  PYTHONPATH=src python -m benchmarks.obs_overhead [--smoke]
      [--nodes N] [--units N] [--inject-crash] [--inject-partition]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import time

from benchmarks.cluster_dispatch import crash_unit_key, fleet_spec


def _run(spec, root, *, executor="cluster", nodes=3, spans=False,
         fault_plan=None, verbose=False):
    from repro.campaign import ArtifactStore, CampaignRunner
    shutil.rmtree(root, ignore_errors=True)
    kw = {} if executor == "serial" else {"max_workers": nodes,
                                          "heartbeat_timeout_s": 30.0}
    t0 = time.perf_counter()
    result = CampaignRunner(spec, ArtifactStore(root), executor=executor,
                            fault_plan=fault_plan, spans=spans, **kw).run(
        verbose=verbose)
    wall = time.perf_counter() - t0
    if not result.ok:
        raise AssertionError(
            f"{executor} campaign (spans={spans}) failed: "
            f"{[(o.key, o.error) for o in result.failed()]}")
    return result, wall


def run_obs_bench(*, n_units: int, n_cores: int, max_measurements: int,
                  nodes: int, inject_crash: bool, inject_partition: bool,
                  store_root: str, verbose: bool = False):
    """Returns (rows, metrics).  Raises AssertionError on any broken
    invariant — bit-identity, export schema, or profile coherence."""
    from repro.campaign.workqueue import FaultPlan, fault_marker_path
    from repro.obs import (to_trace_events, validate_trace_events,
                           write_trace_events)
    from repro.obs.profile import collect_span_rows, profile_campaign

    spec = fleet_spec(n_units, n_cores=n_cores,
                      max_measurements=max_measurements)
    root = lambda name: os.path.join(store_root, name)        # noqa: E731

    ref, t_serial = _run(spec, root("serial"), executor="serial",
                         verbose=verbose)
    digest = ref.campaign.content_digest()

    # untimed warmup: the first cluster run pays one-time costs (backend
    # compile caches, thread pools) that would bias the off-vs-on delta
    _run(spec, root("warmup"), nodes=nodes, verbose=verbose)

    off, t_off = _run(spec, root("spans-off"), nodes=nodes, verbose=verbose)
    if off.campaign.content_digest() != digest:
        raise AssertionError("spans-off cluster store diverged from serial")
    if off.campaign.list_span_files():
        raise AssertionError("spans-off run recorded span files")

    on, t_on = _run(spec, root("spans-on"), nodes=nodes, spans=True,
                    verbose=verbose)
    if on.campaign.content_digest() != digest:
        raise AssertionError(
            "BIT-IDENTITY BROKEN: spans-on store diverged from serial — "
            "the recorder perturbed a measurement artifact")
    span_files = on.campaign.list_span_files()
    if not any(os.path.basename(p) == "driver.jsonl" for p in span_files):
        raise AssertionError(f"no driver span file in {span_files}")

    rows_on = collect_span_rows(on.campaign)
    trace_path = os.path.join(store_root, "spans.trace.json")
    write_trace_events(trace_path, rows_on)      # raises on schema errors
    with open(trace_path) as f:
        errors = validate_trace_events(json.load(f))
    if errors:
        raise AssertionError(f"Perfetto export invalid: {errors}")

    t0 = time.perf_counter()
    doc = profile_campaign(on.campaign)
    t_profile = time.perf_counter() - t0
    if doc.get("empty") or doc.get("dominant") is None:
        raise AssertionError(f"profile found no dominant cost: {doc}")
    crit = doc["critical_path"]["total_s"]
    wall = doc["root"]["wall_s"]
    if abs(crit - wall) > 1e-6 * max(1.0, wall):
        raise AssertionError(
            f"critical path ({crit:.6f}s) does not tile the campaign "
            f"root ({wall:.6f}s)")

    overhead_pct = (t_on - t_off) / t_off * 100.0 if t_off > 0 else 0.0
    rows = [
        ("obs_serial_ref", t_serial * 1e6,
         f"units={n_units} wall_s={t_serial:.2f}"),
        ("obs_cluster_baseline", t_off * 1e6,
         f"nodes={nodes} wall_s={t_off:.2f} spans=off"),
        ("obs_spans_on", t_on * 1e6,
         f"nodes={nodes} wall_s={t_on:.2f} overhead={overhead_pct:.2f}% "
         f"span_rows={len(rows_on)} actors={len(doc['actors'])} "
         f"bit_identical=True"),
        ("obs_profile_analyze", t_profile * 1e6,
         f"spans={doc['spans']} events={doc['events']} "
         f"dominant_cat={doc['dominant']['cat']} "
         f"dominant_frac={doc['dominant']['frac']:.2f}"),
    ]

    chaos = "+".join(n for n, flag in (("crash", inject_crash),
                                       ("partition", inject_partition))
                     if flag)
    if chaos:
        faults = {}
        if inject_crash:
            faults["node_crash_after_pairs"] = {crash_unit_key(spec): 2}
        if inject_partition:
            faults["store_partition"] = (2, 4)
        plan = FaultPlan.make(**faults)
        cand, t_chaos = _run(spec, root("chaos"), nodes=nodes, spans=True,
                             fault_plan=plan, verbose=verbose)
        if cand.campaign.content_digest() != digest:
            raise AssertionError(
                "chaos spans-on store diverged from serial — recording "
                "broke the recovery path's bit-identity")
        if inject_crash:
            marker = fault_marker_path(cand.campaign, crash_unit_key(spec),
                                       "node_crash")
            if not os.path.exists(marker):
                raise AssertionError("injected node crash never fired")
        chaos_rows = collect_span_rows(cand.campaign)
        if not chaos_rows:
            raise AssertionError("chaos run recorded no span rows")
        errors = validate_trace_events(to_trace_events(chaos_rows))
        if errors:
            raise AssertionError(f"chaos Perfetto export invalid: {errors}")
        chaos_doc = profile_campaign(cand.campaign)
        rows.append(
            ("obs_chaos_spans", t_chaos * 1e6,
             f"chaos={chaos} wall_s={t_chaos:.2f} bit_identical=True "
             f"span_rows={len(chaos_rows)} "
             f"requeues={chaos_doc['event_counts'].get('sched.requeue', 0)}"
             ))

    metrics = {"t_serial": t_serial, "t_off": t_off, "t_on": t_on,
               "overhead_pct": overhead_pct, "t_profile": t_profile,
               "digest": digest, "span_rows": len(rows_on)}
    return rows, metrics


def bench_obs():
    """benchmarks.run entry point -> BENCH_obs.json."""
    from repro.core.paths import results_dir
    # nodes are threads, so 3 of them work on any host — and give the
    # merged span tree real multi-actor coverage (driver + 3 node files)
    rows, metrics = run_obs_bench(
        n_units=6, n_cores=8, max_measurements=8,
        nodes=3, inject_crash=True,
        inject_partition=False, store_root=results_dir("obs-overhead"))
    # loose sanity ceiling only: the strict <5% bar is CI's best-of-two
    # gate (profile-smoke); a blown ceiling here means recording landed
    # on a measurement hot path, not scheduler noise
    assert metrics["overhead_pct"] < 25.0, (
        f"span overhead {metrics['overhead_pct']:.1f}% is far over "
        "budget — recording is perturbing the campaign")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fleet (4 small units)")
    ap.add_argument("--nodes", type=int,
                    default=min(3, os.cpu_count() or 1))
    ap.add_argument("--units", type=int, default=None,
                    help="fleet size (default: 4 smoke / 6 full)")
    ap.add_argument("--inject-crash", action="store_true",
                    help="also run a node-kill chaos campaign with spans "
                         "on; its store must stay bit-identical")
    ap.add_argument("--inject-partition", action="store_true",
                    help="partition the driver from the store for a "
                         "window of ops during the chaos run")
    ap.add_argument("--store-root", default=None,
                    help="scratch store root (default: "
                         "$REPRO_RESULTS_DIR/obs-overhead)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.core.paths import results_dir
    n_units = args.units or (4 if args.smoke else 6)
    shape = (dict(n_cores=6, max_measurements=6) if args.smoke
             else dict(n_cores=8, max_measurements=8))
    rows, metrics = run_obs_bench(
        n_units=n_units, nodes=args.nodes,
        inject_crash=args.inject_crash,
        inject_partition=args.inject_partition,
        store_root=args.store_root or results_dir("obs-overhead"),
        verbose=args.verbose, **shape)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    from benchmarks.run import _emit_json
    _emit_json(results_dir("bench"), "obs", rows,
               sum(us for _, us, _ in rows) / 1e6)
    print(f"ok: bit-identical everywhere, span overhead "
          f"{metrics['overhead_pct']:.2f}%, {metrics['span_rows']} span "
          f"rows, Perfetto export valid; BENCH_obs.json written",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
