"""Benchmark driver — one function per paper table/figure plus the roofline
report.  Prints ``name,us_per_call,derived`` CSV and, unless ``--no-json``,
writes one machine-readable ``BENCH_<name>.json`` per bench under
``$REPRO_RESULTS_DIR/bench`` so the perf trajectory is diffable across PRs.

  PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time
import traceback

_SPEEDUP_RE = re.compile(r"speedup[=:]\s*([0-9.]+)")


def _emit_json(out_dir: str, bench_name: str, rows: list, wall_s: float
               ) -> None:
    """BENCH_<name>.json: per-op wall time + any speedup-vs-baseline the
    derived string reports."""
    doc = {"bench": bench_name, "wall_s": wall_s,
           "rows": [{"op": name, "us_per_call": us, "derived": derived,
                     **({"speedup": float(m.group(1))}
                        if (m := _SPEEDUP_RE.search(derived)) else {})}
                    for name, us, derived in rows]}
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench_name}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--no-json", action="store_true",
                    help="skip BENCH_<name>.json emission")
    args = ap.parse_args()

    from repro.core.paths import results_dir

    from benchmarks.analysis_speedup import bench_analysis
    from benchmarks.campaign_scale import bench_campaign
    from benchmarks.cluster_dispatch import bench_cluster
    from benchmarks.governor_energy import bench_governor_energy
    from benchmarks.kernel_bench import (bench_flash_attention_kernel,
                                         bench_microbench_kernel,
                                         bench_ssd_kernel,
                                         bench_xla_attention_paths)
    from benchmarks.monitor_ingest import bench_monitor
    from benchmarks.obs_overhead import bench_obs
    from benchmarks.paper_tables import (bench_dbscan_adaptive,
                                         bench_fig3_heatmaps,
                                         bench_fig4_asymmetry,
                                         bench_fig56_clusters,
                                         bench_fig789_variability,
                                         bench_phase1_two_sigma,
                                         bench_table2_summary)
    from benchmarks.roofline_report import bench_roofline_table
    from benchmarks.sweep_e2e import bench_sweep
    from benchmarks.trace_overhead import bench_trace
    from benchmarks.wait_speedup import bench_wait_vectorized

    benches = [
        bench_wait_vectorized,       # simulator hot path (session refactor)
        bench_sweep,                 # end-to-end batched sweep engine
        bench_analysis,              # sorted-window analysis engine
        bench_campaign,              # process-parallel fleet scaling
        bench_cluster,               # multi-node dispatch under chaos
        bench_trace,                 # telemetry recorder overhead (<5% bar)
        bench_obs,                   # span profiler overhead (<5% bar)
        bench_monitor,               # fleet monitor ingest + detection delay
        bench_phase1_two_sigma,      # §V-A
        bench_dbscan_adaptive,       # Alg. 3
        bench_table2_summary,        # Table II (+ ground-truth recovery)
        bench_fig3_heatmaps,         # Fig. 3
        bench_fig4_asymmetry,        # Fig. 4
        bench_fig56_clusters,        # Figs. 5/6 + §VII-B
        bench_fig789_variability,    # Figs. 7-9
        bench_governor_energy,       # §VIII runtime payoff
        bench_microbench_kernel,     # §V workload (Pallas)
        bench_flash_attention_kernel,
        bench_ssd_kernel,
        bench_xla_attention_paths,
        bench_roofline_table,        # deliverable (g)
    ]
    print("name,us_per_call,derived")
    failures = 0
    json_dir = results_dir("bench")
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        bench_name = bench.__name__.removeprefix("bench_")
        t0 = time.perf_counter()
        try:
            rows = list(bench())
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
            if not args.no_json:
                _emit_json(json_dir, bench_name, rows,
                           time.perf_counter() - t0)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{bench.__name__},nan,ERROR {type(e).__name__}: {e}",
                  file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
            if not args.no_json:
                # overwrite any stale success record: perf-trajectory
                # tooling must see the failure, not last run's numbers
                _emit_json(json_dir, bench_name,
                           [("ERROR", None, f"{type(e).__name__}: {e}")],
                           time.perf_counter() - t0)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
