"""Benchmark driver — one function per paper table/figure plus the roofline
report.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks.governor_energy import bench_governor_energy
    from benchmarks.kernel_bench import (bench_flash_attention_kernel,
                                         bench_microbench_kernel,
                                         bench_ssd_kernel,
                                         bench_xla_attention_paths)
    from benchmarks.paper_tables import (bench_dbscan_adaptive,
                                         bench_fig3_heatmaps,
                                         bench_fig4_asymmetry,
                                         bench_fig56_clusters,
                                         bench_fig789_variability,
                                         bench_phase1_two_sigma,
                                         bench_table2_summary)
    from benchmarks.roofline_report import bench_roofline_table
    from benchmarks.wait_speedup import bench_wait_vectorized

    benches = [
        bench_wait_vectorized,       # simulator hot path (session refactor)
        bench_phase1_two_sigma,      # §V-A
        bench_dbscan_adaptive,       # Alg. 3
        bench_table2_summary,        # Table II (+ ground-truth recovery)
        bench_fig3_heatmaps,         # Fig. 3
        bench_fig4_asymmetry,        # Fig. 4
        bench_fig56_clusters,        # Figs. 5/6 + §VII-B
        bench_fig789_variability,    # Figs. 7-9
        bench_governor_energy,       # §VIII runtime payoff
        bench_microbench_kernel,     # §V workload (Pallas)
        bench_flash_attention_kernel,
        bench_ssd_kernel,
        bench_xla_attention_paths,
        bench_roofline_table,        # deliverable (g)
    ]
    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{bench.__name__},nan,ERROR {type(e).__name__}: {e}",
                  file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
