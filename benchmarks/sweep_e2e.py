"""End-to-end sweep benchmark: the batched engine vs the serial per-pair
loop on the SAME 72-pair grid — wall time of the whole measurement sweep
(paper Alg. 2 over every (f_init, f_target) pair), not analysis
microseconds.

Shape is locked to the paper-scale configuration the acceptance bar is
stated against: rtx6000 (72 cores), 9 evenly spaced frequencies from the
device table -> 72 ordered pairs, 8-iteration measured kernels with a
4-iteration confirmation suffix, 8..24 passes per pair with RSE checks
every 8.  Calibration runs once and is shared by both engines (it is
identical work either way and the paper treats it as a separate phase).

Every invocation asserts the batched engine's per-pair results are
bit-identical to the serial reference — status, retry count, latency
vectors, RSE and ground truth — before reporting any timing.  A speedup
number from a diverged result would be meaningless.

Timing uses ``time.process_time`` (CPU time): the sweep is pure compute,
and shared-runner wall clock adds 20-35% noise that CPU time does not
see.  Best-of-``REPS`` per engine; ``REPRO_BENCH_SMOKE=1`` drops to one
rep and a 3-frequency grid for CI smoke runs.

Acceptance bar: batched >= 5x serial on the full 72-pair grid.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.backends import create_backend
from repro.core.batched_sweep import run_batched_sweep
from repro.core.calibration import calibrate, valid_pairs
from repro.core.evaluation import MeasureConfig
from repro.core.pairtask import PairTask, run_pair_task
from repro.core.workload import WorkloadSpec

KIND = "rtx6000"
SEED = 123
N_FREQS = 9
REPS = 3

SPEC = WorkloadSpec(iters_per_kernel=8, flops_per_iter=256e-3,
                    delay_iters=2, confirm_iters=4)
MEASURE = MeasureConfig(min_measurements=8, max_measurements=24,
                        rse_check_every=8, rse_target=0.0,
                        max_retries=100, min_confirm=4)


def _grid(n_freqs: int):
    """n_freqs evenly spaced entries of the device frequency table plus
    the shared calibration and pair task."""
    opts = {"kind": KIND}
    dev = create_backend("vmapped-sim", **opts, seed=SEED)
    fs = dev.frequencies
    step = (len(fs) - 1) / (n_freqs - 1)
    freqs = sorted({float(fs[round(i * step)]) for i in range(n_freqs)})
    cal = calibrate(dev, freqs, SPEC)
    pairs = valid_pairs(cal)
    task = PairTask.make("vmapped-sim", opts, cal, SPEC, MEASURE)
    return task, pairs


def _assert_identical(pairs, serial, batched) -> None:
    for p in pairs:
        pm_s, gt_s = serial[p]
        pm_b, gt_b = batched[p]
        same = (pm_s.status == pm_b.status
                and pm_s.retries == pm_b.retries
                and pm_s.latencies.shape == pm_b.latencies.shape
                and np.array_equal(pm_s.latencies, pm_b.latencies)
                and (pm_s.rse == pm_b.rse
                     or (np.isinf(pm_s.rse) and np.isinf(pm_b.rse)))
                and repr(gt_s) == repr(gt_b))
        assert same, (
            f"batched result diverged from serial at pair {p}: "
            f"status {pm_s.status}/{pm_b.status} "
            f"retries {pm_s.retries}/{pm_b.retries}")


def bench_sweep():
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    reps = 1 if smoke else REPS
    task, pairs = _grid(3 if smoke else N_FREQS)

    serial_s = batched_s = float("inf")
    serial = batched = None
    for _ in range(reps):
        t0 = time.process_time()
        serial = {p: run_pair_task(task, p) for p in pairs}
        serial_s = min(serial_s, time.process_time() - t0)
        t0 = time.process_time()
        batched = run_batched_sweep(task, pairs)
        batched_s = min(batched_s, time.process_time() - t0)
        _assert_identical(pairs, serial, batched)

    n = len(pairs)
    ratio = serial_s / batched_s
    per_pair_b = batched_s / n * 1e6
    per_pair_s = serial_s / n * 1e6
    statuses = sorted({pm.status for pm, _ in batched.values()})
    yield (f"sweep_serial_{n}pairs", per_pair_s,
           f"total={serial_s:.3f}s cpu, per-pair run_pair_task loop")
    yield (f"sweep_batched_{n}pairs", per_pair_b,
           f"total={batched_s:.3f}s cpu, speedup={ratio:.2f}x, "
           f"bit-identical statuses={statuses}")
