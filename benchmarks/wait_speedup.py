"""Hot-path benchmark: vectorized segment-cumsum timestamp evaluation vs
the seed per-iteration loop it replaced, on a 1000-iteration kernel.

The evaluation runs inside SimulatedAccelerator.wait() under every
calibration kernel, probe and measurement pass, so the whole simulated
campaign scales with it.  Scenarios:

  stable      kernel entirely inside one frequency segment (calibration /
              warm-up shape — the most common kernel in a sweep)
  mid-switch  one frequency change arrives mid-kernel (the phase-2
              measurement shape)

``speedup`` times the two implementations on identical inputs (same RNG
draws, same event timeline — they return bit-identical boundaries, which
is also asserted); ``e2e`` is the full wait() ratio including the shared
RNG-draw and timer-quantization cost.  Acceptance bar: speedup >= 5x.
"""
from __future__ import annotations

import time

import numpy as np

from repro.dvfs import make_device
from repro.dvfs.device_model import SimulatedAccelerator

N_ITERS = 1000
N_CORES = 108
REPS = 5


def _device_state(mid_switch: bool, seed: int = 0):
    """A realistic device mid-sweep + the wait() inputs for one kernel."""
    dev = make_device("a100", seed=seed, n_cores=N_CORES)
    fs = dev.cfg.frequencies
    dev.set_frequency(fs[0])
    dev.run_kernel(64, 40e-6)
    h = dev.launch_kernel(N_ITERS, 40e-6)
    if mid_switch:
        dev.usleep(0.004)
        dev.set_frequency(fs[-1])
    c = dev.cfg
    t0 = np.full(c.n_cores, h.start_dev) \
        + dev.rng.uniform(0, c.core_skew_s, c.n_cores)
    noise = dev.rng.lognormal(0.0, c.iter_noise_sigma,
                              (c.n_cores, N_ITERS))
    ev_t = np.array([e[0] for e in dev._events])
    ev_f = np.array([e[1] for e in dev._events])
    return h.base_iter_s, t0, noise, ev_t, ev_f, max(c.frequencies)


def _time_eval(fn, args) -> float:
    fn(*args)                                   # warm
    t0 = time.perf_counter()
    for _ in range(REPS):
        fn(*args)
    return (time.perf_counter() - t0) / REPS


def _time_wait(impl: str, mid_switch: bool) -> float:
    dev = make_device("a100", seed=1, n_cores=N_CORES, wait_impl=impl)
    fs = dev.cfg.frequencies
    dev.set_frequency(fs[-1])
    dev.run_kernel(8, 40e-6)
    t0 = time.perf_counter()
    for _ in range(REPS):
        if mid_switch:
            dev.set_frequency(fs[0])
            h = dev.launch_kernel(N_ITERS, 40e-6)
            dev.usleep(0.004)
            dev.set_frequency(fs[-1])
            dev.wait(h)
        else:
            dev.run_kernel(N_ITERS, 40e-6)
    return (time.perf_counter() - t0) / REPS


def bench_wait_vectorized():
    rows = []
    for label, mid_switch in (("stable", False), ("mid-switch", True)):
        args = _device_state(mid_switch)
        loop_s = _time_eval(SimulatedAccelerator._eval_timestamps_loop, args)
        vec_s = _time_eval(SimulatedAccelerator._eval_timestamps_vectorized,
                           args)
        same = np.array_equal(
            SimulatedAccelerator._eval_timestamps_loop(*args),
            SimulatedAccelerator._eval_timestamps_vectorized(*args))
        e2e = _time_wait("loop", mid_switch) / _time_wait("vectorized",
                                                          mid_switch)
        rows.append((f"wait_vectorized/{label}", vec_s * 1e6,
                     f"speedup={loop_s / vec_s:.1f}x "
                     f"e2e={e2e:.1f}x loop_us={loop_s*1e6:.0f} "
                     f"identical={same}"))
    return rows
