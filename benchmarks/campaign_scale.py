"""Campaign scaling benchmark: process-parallel fleet measurement.

Runs the same multi-unit simulated fleet twice — ``--executor serial``
(the paper's single-process shape) and ``--executor processes`` (the
fault-tolerant work queue) — into separate stores, verifies the two
artifact sets are **bit-identical** (the determinism contract), and
records the speedup in ``BENCH_campaign.json``.

CI's ``campaign-scale-smoke`` job runs ``--smoke --inject-crash``: a
worker is hard-killed mid-unit (``os._exit`` after two persisted pairs)
and the run must still complete through the requeue path, with the marker
file proving the crash actually fired.

  PYTHONPATH=src python -m benchmarks.campaign_scale [--smoke]
      [--executor processes] [--max-workers N] [--inject-crash]
      [--min-speedup X | auto]

Speedup expectations: units are CPU-bound numpy, so the ceiling is
``min(max_workers, cpu_count, n_units)`` minus process spawn overhead.
``--min-speedup auto`` asserts >= 2x when the host can possibly deliver
it (>= 2 effective workers at full-mode unit sizes) and scales the bar
down honestly on smaller hosts instead of faking parallelism.
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import time

import numpy as np

# fleet shape: one kind, many seeds (distinct modeled boards).  A scaling
# benchmark needs units of comparable cost: with mixed kinds a single
# expensive unit (rtx6000 sweeps cost ~5x a gh200) becomes the makespan
# lower bound and no amount of workers can beat serial by much.  Kind
# heterogeneity is exercised by the recovery tests instead.
_KIND = "gh200"
_FREQS = (345.0, 1155.0, 1980.0)


def fleet_spec(n_units: int, *, n_cores: int, max_measurements: int,
               retries: int = 3):
    from repro.campaign import CampaignSpec, DeviceSpec, MeasureSpec
    measure = MeasureSpec(key="fast", min_measurements=4,
                          max_measurements=max_measurements,
                          rse_check_every=4)
    devices = tuple(
        DeviceSpec.make(f"u{i:02d}-{_KIND}", "simulated",
                        {"kind": _KIND, "n_cores": n_cores, "seed": i},
                        frequencies=_FREQS)
        for i in range(n_units))
    return CampaignSpec("campaign-scale", devices=devices,
                        measures=(measure,), retries=retries)


def crash_unit_key(spec) -> str:
    """The unit the smoke run hard-kills mid-sweep (first in the fleet)."""
    return spec.units()[0].key


def _assert_bit_identical(ref, cand) -> int:
    """Every pair of every unit, compared through the store (what
    consumers read).  Returns the number of pairs compared."""
    n = 0
    ref_tables = {k: ref.campaign.load_table(k) for k in ref.outcomes}
    for key, rt in ref_tables.items():
        ct = cand.campaign.load_table(key)
        if set(rt.pairs) != set(ct.pairs):
            raise AssertionError(f"{key}: pair sets differ")
        for p, pr in rt.pairs.items():
            cp = ct.pairs[p]
            if not (np.array_equal(pr.latencies, cp.latencies)
                    and np.array_equal(pr.outlier_mask, cp.outlier_mask)
                    and pr.status == cp.status
                    and pr.n_clusters == cp.n_clusters):
                raise AssertionError(
                    f"{key} pair {p}: tables are not bit-identical "
                    "between serial and parallel schedules")
            n += 1
    return n


def run_scale(*, n_units: int, n_cores: int, max_measurements: int,
              executor: str, max_workers: int, inject_crash: bool,
              store_root: str, verbose: bool = False):
    """Serial reference vs parallel candidate; returns benchmark rows plus
    the recovery stats (rows feed BENCH_campaign.json)."""
    from repro.campaign import ArtifactStore, CampaignRunner
    from repro.campaign.workqueue import FaultPlan, fault_marker_path

    spec = fleet_spec(n_units, n_cores=n_cores,
                      max_measurements=max_measurements)
    roots = {name: os.path.join(store_root, name)
             for name in ("serial", executor)}
    for r in roots.values():            # fresh stores: measure, not resume
        shutil.rmtree(r, ignore_errors=True)

    t0 = time.perf_counter()
    ref = CampaignRunner(spec, ArtifactStore(roots["serial"])).run(
        verbose=verbose)
    t_serial = time.perf_counter() - t0
    if not ref.ok:
        raise AssertionError(
            f"serial reference failed: {[(o.key, o.error) for o in ref.failed()]}")

    fault_plan = None
    if inject_crash:
        if executor != "processes":
            raise SystemExit("--inject-crash requires --executor processes "
                             "(crashes are recovered by the work queue)")
        fault_plan = FaultPlan.make(
            crash_after_pairs={crash_unit_key(spec): 2})
    t0 = time.perf_counter()
    cand = CampaignRunner(spec, ArtifactStore(roots[executor]),
                          executor=executor, max_workers=max_workers,
                          fault_plan=fault_plan).run(verbose=verbose)
    t_parallel = time.perf_counter() - t0
    if not cand.ok:
        raise AssertionError(
            f"{executor} campaign failed: "
            f"{[(o.key, o.error) for o in cand.failed()]}")

    if inject_crash:
        marker = fault_marker_path(cand.campaign, crash_unit_key(spec),
                                   "crash")
        if not os.path.exists(marker):
            raise AssertionError(
                "injected crash never fired: the smoke run proved nothing "
                f"(missing {marker})")
        if cand.stats.get("crashed_workers", 0) < 1:
            raise AssertionError(
                f"crash fired but the scheduler recorded no dead worker: "
                f"{cand.stats}")
        if cand.stats.get("requeued_units", 0) < 1:
            raise AssertionError(
                f"crashed unit was not requeued: {cand.stats}")

    n_pairs = _assert_bit_identical(ref, cand)
    speedup = t_serial / t_parallel
    eff = min(max_workers, os.cpu_count() or 1, n_units)
    # contention inflation: how much slower each unit ran inside a
    # concurrent worker than serially (manifest wall times).  >1 means the
    # host's cores do not deliver independent throughput (shared memory
    # bandwidth, oversubscribed vCPUs) — a hardware ceiling that caps any
    # process-parallel speedup and is not the scheduler's overhead.
    serial_sum = sum(st.get("wall_s", 0.0) for st in
                     ref.campaign.unit_states().values())
    par_sum = sum(st.get("wall_s", 0.0) for st in
                  cand.campaign.unit_states().values())
    inflation = max(1.0, par_sum / serial_sum) if serial_sum > 0 else 1.0
    # the parallel run's own ideal makespan: its measured unit costs
    # spread perfectly over the workers.  t_parallel/ideal isolates the
    # scheduler's overhead (spawn, queueing, tail imbalance) from both
    # host contention AND run-to-run throughput noise
    ideal = par_sum / eff if eff else float("inf")
    overhead = t_parallel / ideal if ideal > 0 else float("inf")
    rows = [
        ("campaign_serial", t_serial * 1e6,
         f"units={n_units} pairs={n_pairs} wall_s={t_serial:.2f}"),
        (f"campaign_{executor}", t_parallel * 1e6,
         f"speedup={speedup:.2f} workers={max_workers} "
         f"effective={eff} cpus={os.cpu_count()} "
         f"contention_inflation={inflation:.2f} "
         f"sched_overhead={overhead:.2f} "
         f"wall_s={t_parallel:.2f} bit_identical_pairs={n_pairs}"
         + (f" crash_recovered=1 requeued={cand.stats['requeued_units']}"
            if inject_crash else "")),
    ]
    metrics = {"speedup": speedup, "eff": eff, "inflation": inflation,
               "overhead": overhead, "ideal_s": ideal,
               "t_serial": t_serial, "t_parallel": t_parallel}
    return rows, cand.stats, metrics


def check_scaling(metrics: dict, *, smoke: bool,
                  spawn_allowance_s: float = 3.0) -> list[str]:
    """Performance gates; returns failure messages (empty = pass).

    Two gates, separating what the scheduler controls from what the host
    does:

    * **scheduler overhead** (full mode, always): the parallel wall time
      must stay close to the run's own ideal makespan (its measured unit
      costs spread perfectly over the workers) plus a spawn allowance.
      Computed entirely from one run, so noisy-neighbor throughput swings
      between the serial and parallel runs cannot flake it.
    * **the 2x contract** (full mode, capable hosts): where the host
      demonstrably delivers independent core throughput (>= 3 effective
      workers and <= 1.25x contention inflation — CI runners qualify,
      oversubscribed 2-vCPU containers do not), end-to-end speedup must
      reach 2x over serial.

    Smoke mode gates recovery and bit-identity elsewhere and asserts
    nothing about speed: its units are so small that process spawn
    dominates by design."""
    if smoke:
        return []
    fails = []
    eff, inflation = metrics["eff"], metrics["inflation"]
    budget = 1.35 * metrics["ideal_s"] + spawn_allowance_s
    if metrics["t_parallel"] > budget:
        fails.append(
            f"scheduler overhead: parallel wall {metrics['t_parallel']:.2f}s "
            f"exceeds 1.35 x ideal makespan {metrics['ideal_s']:.2f}s "
            f"+ {spawn_allowance_s:.0f}s spawn allowance")
    if eff >= 3 and inflation <= 1.25 and metrics["speedup"] < 2.0:
        fails.append(
            f"2x contract: speedup {metrics['speedup']:.2f}x < 2x on a "
            f"host with {eff} effective workers and only "
            f"{inflation:.2f}x contention inflation")
    return fails


def bench_campaign():
    """benchmarks.run entry point -> BENCH_campaign.json."""
    from repro.core.paths import results_dir
    rows, _, metrics = run_scale(
        n_units=8, n_cores=8, max_measurements=8, executor="processes",
        max_workers=min(4, os.cpu_count() or 1), inject_crash=False,
        store_root=results_dir("campaign-scale"))
    fails = check_scaling(metrics, smoke=False)
    assert not fails, f"campaign scaling regressed: {fails}"
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fleet (4 small units)")
    ap.add_argument("--executor", default="processes",
                    choices=("threads", "processes"))
    ap.add_argument("--max-workers", type=int,
                    default=min(4, os.cpu_count() or 1))
    ap.add_argument("--units", type=int, default=None,
                    help="fleet size (default: 4 smoke / 8 full)")
    ap.add_argument("--inject-crash", action="store_true",
                    help="hard-kill a worker mid-unit; the run must "
                         "complete via requeue (processes only)")
    ap.add_argument("--min-speedup", default="auto",
                    help="fail below this speedup; 'auto' scales the 2x "
                         "bar to the host's effective parallelism")
    ap.add_argument("--store-root", default=None,
                    help="scratch store root (default: "
                         "$REPRO_RESULTS_DIR/campaign-scale)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.core.paths import results_dir
    n_units = args.units or (4 if args.smoke else 8)
    shape = (dict(n_cores=6, max_measurements=6) if args.smoke
             else dict(n_cores=8, max_measurements=8))
    store_root = args.store_root or results_dir("campaign-scale")
    rows, stats, metrics = run_scale(
        n_units=n_units, executor=args.executor,
        max_workers=args.max_workers, inject_crash=args.inject_crash,
        store_root=store_root, verbose=args.verbose, **shape)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"recovery stats: {stats}", file=sys.stderr)

    from benchmarks.run import _emit_json
    _emit_json(results_dir("bench"), "campaign", rows, sum(
        us for _, us, _ in rows) / 1e6)

    if args.min_speedup == "auto":
        fails = check_scaling(metrics, smoke=args.smoke)
    elif metrics["speedup"] < float(args.min_speedup):
        fails = [f"speedup {metrics['speedup']:.2f}x below the explicit "
                 f"{float(args.min_speedup):.2f}x floor"]
    else:
        fails = []
    if fails:
        for f in fails:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print(f"ok: {metrics['speedup']:.2f}x over serial "
          f"({metrics['eff']} effective workers, "
          f"{metrics['inflation']:.2f}x contention inflation, "
          f"{metrics['overhead']:.2f}x scheduler overhead); "
          "BENCH_campaign.json written")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
