"""Benchmarks reproducing each paper table/figure against the three
simulated architectures (Table II, Figs. 3-9) + ground-truth recovery.

All measurement data comes from the shared benchmark campaign in the
artifact store (benchmarks.common.bench_campaign): the first run measures
and persists, subsequent runs query.  Reported times are the per-unit
measurement wall times recorded in the campaign manifest.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (KINDS, bench_campaign, ground_truth_for,
                               table_for, timed, unit_key, wall_us_for)
from repro.campaign.aggregate import comparison_rows
from repro.core.dbscan import adaptive_dbscan
from repro.core.silhouette import silhouette_score
from repro.core import stats as statsmod


def bench_table2_summary():
    """Table II: min/mean/max of worst- and best-case latencies per GPU —
    pulled through the campaign aggregation layer."""
    campaign = bench_campaign()
    rows = []
    by_unit = {r["unit"]: r for r in comparison_rows(campaign)}
    for kind in KINDS:
        r = by_unit[unit_key(kind)]
        rows.append((f"table2/{kind}", wall_us_for(kind),
                     f"worst[min/mean/max]={r['worst_min_ms']:.1f}/"
                     f"{r['worst_mean_ms']:.1f}/{r['worst_max_ms']:.1f}ms "
                     f"best[min/mean/max]={r['best_min_ms']:.1f}/"
                     f"{r['best_mean_ms']:.1f}/{r['best_max_ms']:.1f}ms "
                     f"pairs={r['n_pairs']}"))
        # ground-truth recovery (the validation the paper can't do) — the
        # store persists the simulator's true latencies next to the CSVs
        gt = ground_truth_for(kind)
        table = table_for(kind)
        errs = []
        for (fi, ft), pr in table.pairs.items():
            if pr.status != "ok" or not pr.clean.size or (fi, ft) not in gt:
                continue
            t = gt[(fi, ft)]
            errs.append(abs(pr.worst_case - t) / t)
        rows.append((f"table2/{kind}/ground_truth", 0.0,
                     f"median_rel_err={np.median(errs):.2%} n={len(errs)}"))
    return rows


def bench_fig3_heatmaps():
    """Fig. 3: worst-case heatmaps; target-frequency row pattern on GH200."""
    rows = []
    for kind in KINDS:
        table = table_for(kind, 4, 1)
        m, inits, targets = table.heatmap("worst")
        col_std = np.nanstd(np.nanmean(m, axis=0))   # across targets
        row_std = np.nanstd(np.nanmean(m, axis=1))   # across inits
        rows.append((f"fig3/{kind}", wall_us_for(kind, 4, 1),
                     f"max={np.nanmax(m)*1e3:.1f}ms target_effect/init_effect="
                     f"{col_std/max(row_std,1e-12):.2f}"))
    return rows


def bench_fig4_asymmetry():
    """Fig. 4: up vs down switching-latency distributions (A100 asymmetry)."""
    rows = []
    for kind in KINDS:
        table = table_for(kind, 4, 2)
        a = table.asymmetry()
        up, dn = a["increase"], a["decrease"]
        rows.append((f"fig4/{kind}", wall_us_for(kind, 4, 2),
                     f"up_mean={up['mean_ms']:.1f}ms down_mean="
                     f"{dn['mean_ms']:.1f}ms ratio="
                     f"{up['mean_ms']/max(dn['mean_ms'],1e-9):.2f}"))
    return rows


def bench_fig56_clusters():
    """Figs. 5/6 + §VII-B: multi-cluster pairs and silhouette scores."""
    rows = []
    for kind in KINDS:
        table = table_for(kind, 4, 3)
        ok = [p for p in table.pairs.values() if p.status == "ok"]
        one = np.mean([p.n_clusters == 1 for p in ok]) if ok else 0
        multi = [p for p in ok if p.n_clusters >= 2 and np.isfinite(p.silhouette)]
        sil = np.mean([p.silhouette for p in multi]) if multi else float("nan")
        rows.append((f"fig56/{kind}", wall_us_for(kind, 4, 3),
                     f"one_cluster={one:.0%} max_clusters="
                     f"{max((p.n_clusters for p in ok), default=0)} "
                     f"mean_silhouette={sil:.2f}"))
    return rows


def bench_fig789_variability():
    """Figs. 7-9: manufacturing variability across four A100 units."""
    tables = []
    us_tot = 0.0
    for unit in range(4):
        tables.append(table_for("a100", 3, 10 + unit, unit))
        us_tot += wall_us_for("a100", 3, 10 + unit, unit)
    pairs = set.intersection(*[set(t.pairs) for t in tables])
    spreads_min, spreads_max = [], []
    worst_unit = np.zeros(4)
    for pr_key in pairs:
        best = [t.pairs[pr_key].best_case for t in tables]
        worst = [t.pairs[pr_key].worst_case for t in tables]
        if any(np.isnan(best)) or any(np.isnan(worst)):
            continue
        spreads_min.append(max(best) - min(best))
        spreads_max.append(max(worst) - min(worst))
        worst_unit[int(np.argmax(worst))] += 1
    dominance = worst_unit.max() / max(worst_unit.sum(), 1)
    return [("fig789/a100x4", us_tot,
             f"pairs={len(spreads_min)} min_range_mean="
             f"{np.mean(spreads_min)*1e3:.2f}ms max_range_mean="
             f"{np.mean(spreads_max)*1e3:.2f}ms "
             f"worst_unit_dominance={dominance:.0%} (no unit consistently "
             f"worse)" )]


def bench_phase1_two_sigma():
    """§V-A: the 2SE band starves detection at accelerator sample counts."""
    rng = np.random.default_rng(0)
    def run():
        big = rng.normal(40e-6, 1e-6, 1_000_000)
        big = np.round(big / 1e-6) * 1e-6
        s = statsmod.mean_std(big)
        lo_se, hi_se = statsmod.two_se_band(s)
        lo_sg, hi_sg = statsmod.two_sigma_band(s)
        return (np.mean((big >= lo_se) & (big <= hi_se)),
                np.mean((big >= lo_sg) & (big <= hi_sg)))
    (f_se, f_sg), us = timed(run)
    return [("phase1/2sigma_vs_2se", us,
             f"inside_2SE={f_se:.1%} inside_2sigma={f_sg:.1%} (n=1e6)")]


def bench_dbscan_adaptive():
    """Alg. 3 on a GH200-style multi-cluster pair + outliers."""
    rng = np.random.default_rng(5)
    lat = np.concatenate([rng.normal(30e-3, 0.5e-3, 150),
                          rng.normal(55e-3, 0.5e-3, 40),
                          rng.uniform(0.2, 0.5, 6)])
    res, us = timed(adaptive_dbscan, lat)
    sil = silhouette_score(lat, res.labels)
    return [("alg3/dbscan", us,
             f"clusters={res.n_clusters} noise={res.noise_ratio:.1%} "
             f"minPts={res.min_pts} silhouette={sil:.2f}")]
