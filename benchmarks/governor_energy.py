"""The paper's §VIII payoff: a latency-table-aware governor vs baselines,
with region profiles taken from REAL dry-run roofline cells."""
from __future__ import annotations

import glob
import json


from benchmarks.common import bench_campaign, unit_key, wall_us_for
from repro.core.paths import results_dir
from repro.dvfs.governor import (Governor, oblivious_governor_sim, static_sim)
from repro.dvfs.planner import Region, regions_from_cell
from repro.dvfs.power_model import PowerModel


def _regions():
    cells = sorted(glob.glob(
        results_dir("dryrun", "*__train_4k__single.json")))
    for c in cells:
        cell = json.load(open(c))
        if cell["status"] == "ok":
            return regions_from_cell(cell), cell["arch"]
    return ([Region("compute", 0.3), Region("memory", 0.1),
             Region("collective", 0.1), Region("host", 0.01)], "synthetic")


def bench_governor_energy():
    regions, src = _regions()
    rows = []
    campaign = bench_campaign()
    for kind in ("a100", "gh200"):
        us = wall_us_for(kind, 4, 21)
        # fleet path: governor built straight from stored artifacts
        gov = Governor.from_campaign(campaign, unit_key(kind, 4, 21))
        table, freqs, power = gov.table, gov.freqs, gov.power
        stream = regions * 100
        aware = gov.simulate(stream)
        obliv = oblivious_governor_sim(table, power, freqs, stream)
        stat = static_sim(power, freqs, stream)
        save_vs_static = 1 - aware.energy_j / stat.energy_j
        edp_gain = 1 - (aware.energy_j * aware.time_s) / (obliv.energy_j * obliv.time_s)
        rows.append((f"governor/{kind}[{src}]", us,
                     f"energy_save_vs_static={save_vs_static:.1%} "
                     f"slowdown={aware.time_s/stat.time_s-1:+.1%} "
                     f"EDP_gain_vs_oblivious={edp_gain:.1%} "
                     f"switches={aware.switches} suppressed="
                     f"{aware.suppressed_short}"))
    return rows
