"""Shared benchmark harness, backed by the campaign artifact store.

The paper-table benchmarks used to re-measure every simulated device on
every invocation.  They now declare ONE benchmark campaign (all the device
x seed x frequency-subset variants the tables need) and read the artifact
store: the first `benchmarks.run` invocation measures and persists, later
invocations (and anything else — notebooks, CI, the governor) query the
same content-addressed artifacts.  Delete
``$REPRO_RESULTS_DIR/campaigns`` (default ``results/campaigns``) to force
remeasurement; change a spec parameter and the campaign id changes with it.
"""
from __future__ import annotations

import time

from repro.campaign import (ArtifactStore, Campaign, CampaignSpec,
                            DeviceSpec, MeasureSpec, run_campaign)

# fast-but-meaningful defaults for the simulated measurement campaign
FAST_MEASURE = MeasureSpec(key="fast", min_measurements=5,
                           max_measurements=8, rse_check_every=5)
N_CORES = 6
BACKEND = "vmapped-sim"          # the batched always-vectorized simulator

KINDS = ("rtx6000", "a100", "gh200")

# every (kind, n_freqs, seed, unit_seed) variant the paper-table benches
# consume; one campaign unit each
BENCH_VARIANTS = (
    [(kind, 4, s, 0) for kind in KINDS for s in (0, 1, 2, 3)]   # tbl2/figs3-6
    + [("a100", 3, 10 + u, u) for u in range(4)]                # figs 7-9
    + [(kind, 4, 21, 0) for kind in ("a100", "gh200")]          # governor
)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def unit_key(kind: str, n_freqs: int = 4, seed: int = 0,
             unit_seed: int = 0) -> str:
    return f"{kind}-f{n_freqs}s{seed}u{unit_seed}@{FAST_MEASURE.key}"


def _device(kind: str, n_freqs: int, seed: int, unit_seed: int) -> DeviceSpec:
    return DeviceSpec.make(
        f"{kind}-f{n_freqs}s{seed}u{unit_seed}", BACKEND,
        {"kind": kind, "seed": seed, "unit_seed": unit_seed,
         "n_cores": N_CORES},
        n_freqs=n_freqs)


def bench_spec() -> CampaignSpec:
    return CampaignSpec(
        name="paper-tables",
        devices=tuple(_device(*v) for v in BENCH_VARIANTS),
        measures=(FAST_MEASURE,), retries=1)


_CAMPAIGN: Campaign | None = None


def bench_campaign() -> Campaign:
    """Run-or-load the benchmark campaign (cached per process; persisted
    across processes in the artifact store)."""
    global _CAMPAIGN
    if _CAMPAIGN is None:
        result = run_campaign(bench_spec(), ArtifactStore(),
                              executor="threads", max_workers=4)
        bad = result.failed()
        if bad:
            raise RuntimeError(
                f"benchmark campaign units failed: "
                f"{[(o.key, o.error) for o in bad]}")
        _CAMPAIGN = result.campaign
    return _CAMPAIGN


def table_for(kind: str, n_freqs: int = 4, seed: int = 0,
              unit_seed: int = 0):
    return bench_campaign().load_table(unit_key(kind, n_freqs, seed,
                                                unit_seed))


def ground_truth_for(kind: str, n_freqs: int = 4, seed: int = 0,
                     unit_seed: int = 0) -> dict:
    return bench_campaign().ground_truth(unit_key(kind, n_freqs, seed,
                                                  unit_seed))


def wall_us_for(kind: str, n_freqs: int = 4, seed: int = 0,
                unit_seed: int = 0) -> float:
    """Measurement wall time of the unit (us) as recorded in the manifest —
    stable across cached re-reads, so benchmark CSVs stay comparable."""
    st = bench_campaign().unit_states()[unit_key(kind, n_freqs, seed,
                                                 unit_seed)]
    return float(st.get("wall_s", 0.0)) * 1e6
