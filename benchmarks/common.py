"""Shared benchmark harness: one entry per paper table/figure.

Each bench function returns rows of (name, us_per_call, derived) where
``us_per_call`` is the wall time of the benchmark's core computation and
``derived`` a short result string tied to the paper artifact it reproduces.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.evaluation import MeasureConfig
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig)

# fast-but-meaningful defaults for the simulated measurement campaign
FAST = MeasureConfig(min_measurements=5, max_measurements=8,
                     rse_check_every=5)
N_CORES = 6
BACKEND = "vmapped-sim"          # the batched always-vectorized simulator


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def freq_subset(dev, n=5):
    fs = dev.frequencies
    idx = np.linspace(0, len(fs) - 1, n).astype(int)
    return [float(fs[i]) for i in idx]


def measure_session(kind: str, n_freqs: int = 4, seed: int = 0,
                    unit_seed: int = 0) -> MeasurementSession:
    from repro.backends import create_backend
    dev = create_backend(BACKEND, kind=kind, seed=seed, unit_seed=unit_seed,
                         n_cores=N_CORES)
    return MeasurementSession(
        dev, freq_subset(dev, n_freqs),
        SessionConfig(latest=LatestConfig(measure=FAST)),
        device_name=kind, device_index=unit_seed)


def measure_table(kind: str, n_freqs: int = 4, seed: int = 0,
                  unit_seed: int = 0):
    session = measure_session(kind, n_freqs, seed, unit_seed)
    table = session.run()
    return session.device, table
