"""Cross-architecture switching-latency report, fully offline.

The paper's Table II compares switching latency across three GPUs — all
single-clock devices. This walkthrough extends the comparison across
*architectures* with different frequency-domain structure:

  rtx6000-like GPU   one clock ladder, bare-MHz frequency keys
  multi-domain-sim   independent core + uncore/memory ladders; latency
                     depends on which domain moves, and cross-domain
                     transitions pay both legs plus a coupling penalty
  pstate-sim         m1n1-style e-/p-core pstate clusters on different
                     ladders, with a cluster-migration cost

One campaign spec covers all three (operating points spelled
"domain:mhz" — see docs/backends.md), the scheduler measures each unit
through the identical phase 1-3 pipeline, and the report renders:

  * the classic cross-device Table II, and
  * the domain breakdown — per-unit latency by transition class
    ("core", "uncore", "core->uncore", "ecore->pcore", ...) — which
    only appears because the campaign measured domain-encoded points;
    single-domain campaigns keep byte-identical report output.

  PYTHONPATH=src python examples/cross_arch_report.py

Equivalent CLI round-trip:

  PYTHONPATH=src python -m repro.campaign run spec.json
  PYTHONPATH=src python -m repro.campaign report <campaign-id>
"""
from repro.campaign import (ArtifactStore, CampaignSpec, DeviceSpec,
                            MeasureSpec, report_markdown, run_campaign)
from repro.campaign.aggregate import campaign_has_domains, domain_rows
from repro.core.freqkey import transition_class

FAST = MeasureSpec(key="fast", min_measurements=6, max_measurements=8,
                   rse_check_every=6)

spec = CampaignSpec(
    name="cross-arch",
    devices=(
        # the paper's GPU shape: one ladder, bare MHz
        DeviceSpec.make("rtx6000", "vmapped-sim",
                        {"kind": "rtx6000", "n_cores": 6}, n_freqs=3),
        # two clock domains; ops spelled "domain:mhz"
        DeviceSpec.make("multidomain", "multi-domain-sim",
                        {"n_cores": 8},
                        frequencies=["core:600", "core:1500",
                                     "uncore:300", "uncore:600"]),
        # per-cluster pstates, m1n1 M1 ladders
        DeviceSpec.make("pstate", "pstate-sim",
                        {"n_cores": 6},
                        frequencies=["ecore:600", "ecore:2064",
                                     "pcore:600", "pcore:3204"]),
    ),
    measures=(FAST,))

store = ArtifactStore()    # $REPRO_RESULTS_DIR/campaigns
print(f"running campaign {spec.campaign_id()} "
      f"({len(spec.units())} units)...")
result = run_campaign(spec, store, verbose=True)
assert result.ok, [o.error for o in result.failed()]

print()
print(report_markdown(result.campaign))

# the domain breakdown is also available as flat rows for tooling
assert campaign_has_domains(result.campaign)
rows = domain_rows(result.campaign)
cross = [r for r in rows if "->" in r["transition"]]
same = [r for r in rows if "->" not in r["transition"]]
assert cross, "cross-domain transitions must be measured"
print(f"{len(same)} same-domain and {len(cross)} cross-domain "
      "transition classes measured.")

# the paper's qualitative finding, now across architectures: WHICH clock
# moves matters as much as which device you bought
md = result.campaign.load_table("multidomain@fast")
by_class = {}
for (fi, ft), pr in md.pairs.items():
    by_class.setdefault(transition_class(fi, ft), []).append(pr.mean)
core = min(by_class["core"])
uncore = min(by_class["uncore"])
assert core < uncore, "core relocks are faster than uncore retrains"
print(f"multidomain: fastest core switch {core * 1e3:.1f} ms vs fastest "
      f"uncore switch {uncore * 1e3:.1f} ms — same device, "
      f"{uncore / core:.0f}x apart by domain alone.")
