"""LATEST-style CLI sweep over the simulated GPU architectures with CSV
output — the tool-usage surface of paper §VI, now with backend selection,
thread-parallel scheduling and resume-from-disk.

  PYTHONPATH=src python examples/measure_sweep.py --device a100 \
      --freqs 210,705,1410 --rse 0.05 --min 8 --max 24

  # pluggable backend + parallel workers + resumable state:
  PYTHONPATH=src python examples/measure_sweep.py --backend vmapped-sim \
      --parallel 4 --state results/sweep_state
  (interrupt it; the same command resumes where it stopped)

  # the batched engine: same table, one fused program (prints speedup)
  PYTHONPATH=src python examples/measure_sweep.py --backend vmapped-sim \
      --engine batched
"""
import argparse
import time

from repro.backends import create_backend, list_backends
from repro.core.evaluation import MeasureConfig
from repro.core.paths import results_dir
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig)

ap = argparse.ArgumentParser()
ap.add_argument("--device", choices=("a100", "gh200", "rtx6000"),
                default="a100")
ap.add_argument("--backend", choices=list_backends(), default="simulated")
ap.add_argument("--device-index", type=int, default=0)
ap.add_argument("--freqs", default=None,
                help="comma-separated MHz list (mandatory arg in LATEST)")
ap.add_argument("--rse", type=float, default=0.05)
ap.add_argument("--min", type=int, default=8, dest="min_meas")
ap.add_argument("--max", type=int, default=24, dest="max_meas")
ap.add_argument("--parallel", type=int, default=0,
                help="thread workers, one independent device each "
                     "(0 = serial)")
ap.add_argument("--engine", choices=("serial", "batched"), default="serial",
                help="batched = the whole pair grid as lock-stepped "
                     "vectorized dispatches (bit-identical results); "
                     "prints the speedup over a serial reference sweep")
ap.add_argument("--state", default=None,
                help="session dir: partial results persist here and a "
                     "re-run resumes instead of restarting")
ap.add_argument("--out", default=None,
                help="CSV dir (default: $REPRO_RESULTS_DIR/latest_csv)")
args = ap.parse_args()

dev = create_backend(args.backend, kind=args.device, seed=args.device_index,
                     unit_seed=args.device_index, n_cores=8)
if args.freqs:
    freqs = [float(f) for f in args.freqs.split(",")]
else:
    fs = dev.frequencies
    freqs = [float(fs[i]) for i in (0, len(fs) // 2, -1)]

def build_session(engine):
    return MeasurementSession(
        dev, freqs,
        SessionConfig(
            latest=LatestConfig(measure=MeasureConfig(
                rse_target=args.rse, min_measurements=args.min_meas,
                max_measurements=args.max_meas)),
            executor="threads" if args.parallel else "serial",
            max_workers=args.parallel or 1,
            out_dir=args.state),
        backend=args.backend,
        backend_options={"kind": args.device, "seed": args.device_index,
                         "unit_seed": args.device_index, "n_cores": 8},
        device_name=args.device, device_index=args.device_index,
        engine=engine)


session = build_session(args.engine)
t0 = time.perf_counter()
table = session.run(verbose=True)
sweep_s = time.perf_counter() - t0

if args.engine == "batched" and args.state is None:
    # in-memory runs re-measure the same grid serially to show the win
    # (resumable runs skip it: the reference would re-measure done pairs)
    ref = build_session("serial")
    t0 = time.perf_counter()
    ref.run(verbose=False)
    serial_s = time.perf_counter() - t0
    print(f"\nbatched sweep {sweep_s:.2f}s vs serial {serial_s:.2f}s "
          f"-> {serial_s / max(sweep_s, 1e-9):.1f}x speedup "
          "(identical tables by construction; see tests/benchmarks)")
out = args.out if args.out is not None else results_dir("latest_csv")
paths = table.save_csv(out)
print(f"\nsummary: {table.summary()}")
print(f"{len(paths)} CSVs -> {out}")
