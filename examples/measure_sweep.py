"""LATEST-style CLI sweep over the three simulated GPU architectures with
CSV output — the tool-usage surface of paper §VI.

  PYTHONPATH=src python examples/measure_sweep.py --device a100 \
      --freqs 210,705,1410 --rse 0.05 --min 8 --max 24
"""
import argparse

from repro.core.evaluation import MeasureConfig
from repro.core.latest import LatestConfig, run_latest
from repro.dvfs import make_device

ap = argparse.ArgumentParser()
ap.add_argument("--device", choices=("a100", "gh200", "rtx6000"),
                default="a100")
ap.add_argument("--device-index", type=int, default=0)
ap.add_argument("--freqs", default=None,
                help="comma-separated MHz list (mandatory arg in LATEST)")
ap.add_argument("--rse", type=float, default=0.05)
ap.add_argument("--min", type=int, default=8, dest="min_meas")
ap.add_argument("--max", type=int, default=24, dest="max_meas")
ap.add_argument("--out", default="results/latest_csv")
args = ap.parse_args()

dev = make_device(args.device, seed=args.device_index,
                  unit_seed=args.device_index, n_cores=8)
if args.freqs:
    freqs = [float(f) for f in args.freqs.split(",")]
else:
    fs = dev.cfg.frequencies
    freqs = [float(fs[i]) for i in (0, len(fs) // 2, -1)]

table = run_latest(
    dev, freqs,
    LatestConfig(measure=MeasureConfig(rse_target=args.rse,
                                       min_measurements=args.min_meas,
                                       max_measurements=args.max_meas)),
    device_name=args.device, device_index=args.device_index,
    verbose=True)
paths = table.save_csv(args.out)
print(f"\nsummary: {table.summary()}")
print(f"{len(paths)} CSVs -> {args.out}")
