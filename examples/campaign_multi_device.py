"""Multi-device measurement campaign, end to end and fully offline.

Reproduces the paper's cross-GPU finding — switching latency varies by
ORDERS of magnitude across devices — by declaring one campaign over three
simulated accelerators with deliberately different ground-truth transition
models (A100-like: fast+asymmetric; GH200-like: target-dominated with bad
targets; RTX6000-like: erratic), then:

1. runs it through the scheduler into the content-addressed artifact store
   (re-running this script resumes from the store instead of re-measuring);
2. prints the cross-device Table-II-style report from the aggregation layer;
3. measures a "next hardware generation" campaign (same fleet, one device's
   unit_seed changed = a different physical unit) and runs the regression
   detector against the first campaign.

  PYTHONPATH=src python examples/campaign_multi_device.py

Equivalent CLI round-trip:

  PYTHONPATH=src python -m repro.campaign run spec.json
  PYTHONPATH=src python -m repro.campaign report <campaign-id>
  PYTHONPATH=src python -m repro.campaign diff <id-a> <id-b>
"""
from repro.campaign import (ArtifactStore, CampaignSpec, DeviceSpec,
                            MeasureSpec, diff_campaigns, diff_markdown,
                            report_markdown, run_campaign)

FAST = MeasureSpec(key="fast", min_measurements=6, max_measurements=8,
                   rse_check_every=6)


def fleet_spec(name: str, rtx_unit_seed: int = 0) -> CampaignSpec:
    def dev(key, kind, unit_seed=0):
        return DeviceSpec.make(key, "vmapped-sim",
                               {"kind": kind, "n_cores": 6, "seed": 0,
                                "unit_seed": unit_seed}, n_freqs=3)
    return CampaignSpec(
        name=name,
        devices=(dev("a100", "a100"), dev("gh200", "gh200"),
                 dev("rtx6000", "rtx6000", unit_seed=rtx_unit_seed)),
        measures=(FAST,))


store = ArtifactStore()    # $REPRO_RESULTS_DIR/campaigns

# -- 1) measure the fleet (resumes if this script already ran) -----------
spec = fleet_spec("three-gpus")
print(f"running campaign {spec.campaign_id()} "
      f"({len(spec.units())} units)...")
result = run_campaign(spec, store, verbose=True)
assert result.ok, [o.error for o in result.failed()]

# -- 2) cross-device report ---------------------------------------------
print()
print(report_markdown(result.campaign))

# -- 3) next generation of the fleet: the RTX unit was swapped ----------
spec2 = fleet_spec("three-gpus-gen2", rtx_unit_seed=5)
print(f"running follow-up campaign {spec2.campaign_id()} "
      "(same fleet, swapped rtx6000 unit)...")
result2 = run_campaign(spec2, store, verbose=True)
assert result2.ok, [o.error for o in result2.failed()]

diff = diff_campaigns(result.campaign, result2.campaign)
print()
print(diff_markdown(diff))
flagged = diff.flagged()
print(f"\n{len(flagged)} pair(s) drifted — every one on the swapped unit:"
      if flagged else "\nno drift detected")
for d in flagged:
    print(f"  {d.unit_key} {d.f_init:.0f}->{d.f_target:.0f} MHz: "
          f"{d.worst_a * 1e3:.1f} -> {d.worst_b * 1e3:.1f} ms "
          f"({d.rel_delta:+.0%}, p={d.p_value:.3g})")
assert all(d.unit_key.startswith("rtx6000") for d in flagged), (
    "only the swapped unit should drift")
