"""Fleet measurement + live drift detection, end to end and fully offline.

Reproduces the paper's cross-GPU finding — switching latency varies by
ORDERS of magnitude across devices — and then closes the loop the paper
motivates: once a fleet's tables are measured, a monitor can watch the
LIVE telemetry streams and name a changed unit without re-running any
campaign.

1. measure a baseline campaign over three simulated accelerators with
   deliberately different ground-truth transition models (A100-like:
   fast+asymmetric; GH200-like: target-dominated; RTX6000-like: erratic)
   through the scheduler into the content-addressed artifact store
   (re-running this script resumes from the store);
2. print the cross-device Table-II-style report;
3. bring up the NEXT generation of the fleet as live devices: same a100
   and gh200 units, but the rtx6000 was physically swapped (different
   unit_seed).  Each device runs behind a TracedBackend whose recorder
   streams every event into one MonitorService via a live tap — the
   monitor reconstructs switch passes, learns calibration baselines from
   the bytes on the wire, runs sequential drift tests against the stored
   campaign tables, and names the swapped unit from its stream alone.

  PYTHONPATH=src python examples/campaign_multi_device.py

Equivalent CLI round-trip:

  PYTHONPATH=src python -m repro.campaign run spec.json
  PYTHONPATH=src python -m repro.campaign report <campaign-id>
  PYTHONPATH=src python -m repro.monitor replay <campaign-id> <trace-dir>
"""
from repro.backends import create_backend
from repro.campaign import (ArtifactStore, CampaignSpec, DeviceSpec,
                            MeasureSpec, report_markdown, run_campaign)
from repro.core.session import MeasurementSession, SessionConfig
from repro.monitor import MonitorConfig, MonitorService, alert_summary
from repro.trace import TracedBackend, TraceRecorder

FAST = MeasureSpec(key="fast", min_measurements=6, max_measurements=8,
                   rse_check_every=6)
FLEET = (("a100", "a100"), ("gh200", "gh200"), ("rtx6000", "rtx6000"))


def fleet_spec(name: str) -> CampaignSpec:
    return CampaignSpec(
        name=name,
        devices=tuple(
            DeviceSpec.make(key, "vmapped-sim",
                            {"kind": kind, "n_cores": 6, "seed": 0,
                             "unit_seed": 0}, n_freqs=3)
            for key, kind in FLEET),
        measures=(FAST,))


store = ArtifactStore()    # $REPRO_RESULTS_DIR/campaigns

# -- 1) measure the baseline fleet (resumes if this script already ran) --
spec = fleet_spec("three-gpus")
print(f"running campaign {spec.campaign_id()} "
      f"({len(spec.units())} units)...")
result = run_campaign(spec, store, verbose=True)
assert result.ok, [o.error for o in result.failed()]

# -- 2) cross-device report ---------------------------------------------
print()
print(report_markdown(result.campaign))

# -- 3) gen2 fleet, live: the rtx6000 unit was swapped -------------------
# Devices are built directly (no campaign, no stored tables on this side):
# everything the monitor learns about gen2 comes from its event streams.
print("\nbringing up the gen2 fleet under the monitor "
      "(rtx6000 unit swapped)...")
# (the sessions run one after another, so earlier devices fall silent in
# stream time while later ones advance the clock — that's an artifact of
# sequential simulation, not real silence, so stale detection is parked)
monitor = MonitorService(result.campaign,
                         MonitorConfig(heartbeat_timeout_s=1e9))
for key, kind in FLEET:
    unit_seed = 5 if key == "rtx6000" else 0     # the swap
    dev = create_backend("vmapped-sim", kind=kind, n_cores=6, seed=1,
                         unit_seed=unit_seed)
    recorder = TraceRecorder()
    traced = TracedBackend(dev, recorder)
    monitor.attach_recorder(key, recorder)        # live tap, pre-session
    session = MeasurementSession(
        traced, DeviceSpec.make(key, n_freqs=3).resolve_frequencies(dev),
        SessionConfig(latest=FAST.to_latest_config()),
        device_name=key)
    session.run(verbose=False)
    st = monitor.status()["devices"][key]
    print(f"  {key}: {st['events']} events, {st['passes']} passes, "
          f"{st['pairs_watched']} pair(s) watched, "
          f"{st['alerts']} alert(s)")

drift_alerts = [doc for _, _, doc in monitor.alerts if doc["kind"] == "drift"]
print(f"\n{len(drift_alerts)} drift alert(s) — every one on the swapped "
      "unit, named from its stream alone:")
for doc in drift_alerts:
    print(f"  {alert_summary(doc)}")
assert drift_alerts, "the swapped unit must be detected"
assert all(doc["device"] == "rtx6000" for doc in drift_alerts), (
    "only the swapped unit should drift")
stored = result.campaign.list_alerts()
assert list(stored) == ["rtx6000@fast"], stored
print(f"\nalert artifacts stored under campaign {result.campaign.campaign_id}"
      f": {stored}")
