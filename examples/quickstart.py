"""Quickstart: measure a simulated accelerator's frequency-switching
latency end-to-end (the paper's full pipeline in ~30 lines), through the
backend registry + MeasurementSession API.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.paths import results_dir

from repro.core.evaluation import MeasureConfig
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig)

# an A100-like simulated accelerator (8 core stand-ins for speed) from the
# registry; "vmapped-sim" batches calibration kernels in one numpy pass
session = MeasurementSession(
    frequencies=[210.0, 705.0, 1095.0, 1410.0],
    cfg=SessionConfig(latest=LatestConfig(
        measure=MeasureConfig(min_measurements=8, max_measurements=16,
                              rse_check_every=8))),
    backend="vmapped-sim",
    backend_options={"kind": "a100", "seed": 0, "n_cores": 8})
table = session.run(verbose=True)
device = session.device

print("\n=== Table II-style summary ===")
for k, v in table.summary().items():
    print(f"  {k}: {v}")

print("\n=== ground-truth check (simulator knows the true latencies) ===")
gt = {}
for h in device.history:
    gt.setdefault((h["from"], h["to"]), []).append(h["true_latency"])
errs = []
for (fi, ft), pr in sorted(table.pairs.items()):
    if pr.status != "ok" or (fi, ft) not in gt:
        continue
    t = max(gt[(fi, ft)])
    errs.append(abs(pr.worst_case - t) / t)
    print(f"  {fi:6.0f}->{ft:6.0f} MHz  measured={pr.worst_case*1e3:7.2f} ms"
          f"  true_max={t*1e3:7.2f} ms")
print(f"\nmedian relative error: {np.median(errs):.1%}")
csv_dir = results_dir("quickstart_csv")
table.save_csv(csv_dir)
print(f"CSVs written to {csv_dir}/ (LATEST naming convention)")
