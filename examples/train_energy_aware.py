"""End-to-end driver: train a ~small LM for a few hundred steps with the
energy-aware DVFS governor planning frequencies from a MEASURED latency
table (the paper's §VIII runtime, integrated with the training loop).

  PYTHONPATH=src python examples/train_energy_aware.py [--steps 200]
"""
import argparse

from repro.backends import create_backend
from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.core.evaluation import MeasureConfig
from repro.core.paths import results_dir
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig)
from repro.dvfs.governor import Governor, oblivious_governor_sim, static_sim
from repro.dvfs.planner import Region
from repro.parallel.sharding import make_env
from repro.runtime.train_loop import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--device", choices=("a100", "gh200", "rtx6000"),
                default="a100")
args = ap.parse_args()

# 1) measure the accelerator's switching latency (paper pipeline) through
#    the session API, then 2) derive the governor straight from the session
print(f"== measuring switching latency ({args.device}-like simulator) ==")
device = create_backend("vmapped-sim", kind=args.device, seed=0, n_cores=8)
fs = device.frequencies
freqs = [float(fs[i]) for i in (0, len(fs) // 2, -1)]
session = MeasurementSession(
    device, freqs,
    SessionConfig(latest=LatestConfig(
        measure=MeasureConfig(min_measurements=6, max_measurements=10,
                              rse_check_every=6))),
    device_name=args.device)
governor = Governor.from_session(session, verbose=True)
table = governor.table
power = governor.power
regions = [Region("compute", 0.25), Region("memory", 0.05),
           Region("collective", 0.08), Region("host", 0.01)]

# 3) train a ~100M-scale (smoke-config) llama with governor hooks
print(f"\n== training with energy-aware governor ({args.steps} steps) ==")
cfg = get_config("llama3-8b", smoke=True)
shape = ShapeSpec("train", 64, 8, "train")
env = make_env(cfg, None)
metrics = train(cfg, shape, env,
                TrainConfig(steps=args.steps, lr=1e-3, warmup=20,
                            log_every=25,
                            checkpoint_dir=results_dir("ckpt_energy_aware"),
                            checkpoint_every=100),
                governor=governor, device=device, regions=regions)

print(f"\nfinal loss {metrics['loss'][-1]:.4f} "
      f"(start {metrics['loss'][0]:.4f})")

# 4) energy accounting: aware vs oblivious vs static
stream = regions * args.steps
aware = metrics["governor"]
obliv = oblivious_governor_sim(table, power, freqs, stream)
stat = static_sim(power, freqs, stream)
print("\n== energy accounting over the training run ==")
print(f"  static f_max : {stat.energy_j/1e3:8.2f} kJ  {stat.time_s:7.1f} s")
print(f"  oblivious    : {obliv.energy_j/1e3:8.2f} kJ  {obliv.time_s:7.1f} s"
      f"  (switch overhead {obliv.switch_overhead_s:.1f} s)")
print(f"  latency-aware: {aware.energy_j/1e3:8.2f} kJ  {aware.time_s:7.1f} s"
      f"  (switch overhead {aware.switch_overhead_s:.1f} s, "
      f"{aware.suppressed_short} switches suppressed)")
print(f"  energy saved vs static: {1-aware.energy_j/stat.energy_j:.1%} at "
      f"{aware.time_s/stat.time_s-1:+.1%} runtime")
