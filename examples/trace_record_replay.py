"""Record a sweep's full telemetry, replay it offline, estimate online.

Demonstrates the trace subsystem end to end:

1. a MeasurementSession sweep runs with recording on — every backend
   interaction (frequency commands, kernel timestamps, clock sync,
   throttle flags) lands in a TraceRecorder;
2. the trace replays with NO device: the identical latency table falls
   out bit for bit (digest-checked);
3. the streaming estimator re-analyses the raw event stream and is
   cross-validated against the batch detector, pass by pass;
4. a governor serves from the measured table with its decisions audited
   into a second trace — the runtime-facing record the paper motivates.

  PYTHONPATH=src python examples/trace_record_replay.py
"""
from repro.core.evaluation import MeasureConfig
from repro.core.paths import results_dir
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig)
from repro.dvfs.governor import Governor
from repro.dvfs.planner import Region
from repro.dvfs.power_model import PowerModel
from repro.trace import Trace, TracedBackend, TraceRecorder
from repro.trace.analyze import analyze_trace, report_markdown
from repro.trace.schema import PLAN


def main() -> None:
    out = results_dir("trace", create=True) + "/example.trace"

    # 1. record a live sweep
    recorder = TraceRecorder()
    session = MeasurementSession(
        cfg=SessionConfig(latest=LatestConfig(measure=MeasureConfig(
            min_measurements=3, max_measurements=5, rse_check_every=3))),
        backend="vmapped-sim",
        backend_options={"kind": "a100", "n_cores": 6},
        frequencies=[210.0, 705.0, 1410.0],
        trace=recorder)
    table = session.run(verbose=True)
    trace = recorder.save(out)
    print(f"\nrecorded {trace.n_events} events -> {out}")

    # 2 + 3. offline: replay determinism + online/batch cross-validation
    report = analyze_trace(Trace.load(out))
    print(report_markdown(report))
    assert report.ok, "replay or online estimation diverged"

    # 4. governor runtime with audited decisions
    audit = TraceRecorder()
    device = TracedBackend(session.device.device, audit)
    gov = Governor(table, PowerModel(f_max_mhz=1410.0), session.frequencies)
    for region in [Region("compute", 5.0), Region("memory", 2.0),
                   Region("compute", 0.001), Region("collective", 3.0)]:
        gov.plan(region, device)
    audited = audit.finish()
    print("\ngovernor audit trail:")
    for i in range(audited.n_events):
        if int(audited.kinds[i]) == PLAN:
            f_from, f_to, dur, _ = audited.cols[i]
            extra = audited.extras[i]
            print(f"  {extra['region']:<11} {dur:7.3f}s  "
                  f"{f_from:6.0f} -> {f_to:6.0f} MHz  ({extra['reason']})")


if __name__ == "__main__":
    main()
