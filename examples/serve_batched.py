"""Batched serving example: prefill + greedy decode across architectures
(dense GQA, MLA compressed-cache, SSM constant-state).

  PYTHONPATH=src python examples/serve_batched.py
"""
import jax

from repro.configs import get_config
from repro.configs.registry import model_module
from repro.configs.shapes import ShapeSpec
from repro.data.synthetic import make_batch
from repro.parallel.sharding import make_env
from repro.runtime.serve_loop import ServeConfig, serve

for arch in ("llama3-8b", "deepseek-v2-236b", "mamba2-130m"):
    cfg = get_config(arch, smoke=True)
    env = make_env(cfg, None)
    mod = model_module(cfg)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, ShapeSpec("s", 32, 4, "prefill"))
    res = serve(cfg, env, params, batch, ServeConfig(max_new_tokens=16))
    print(f"{arch:18s} prefill={res['prefill_s']*1e3:7.1f} ms  "
          f"decode={res['tokens_per_s']:8.1f} tok/s  "
          f"sample={res['tokens'][0][:6].tolist()}")
